//! Cost–latency Pareto frontier (both coordinates minimized).
//!
//! A point `a` dominates `b` iff `a <= b` in both coordinates and `a < b`
//! in at least one. The frontier is the set of non-dominated points;
//! exact duplicates of a frontier point are all kept (neither strictly
//! dominates the other), which matters for advisor candidates that differ
//! only in a latency-neutral attribute.

use crate::util::cmp_f64;

/// `a` dominates `b` (minimization, weak-inequality form).
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 <= b.0 && a.1 <= b.1 && (a.0 < b.0 || a.1 < b.1)
}

/// Indices (ascending) of the non-dominated points — `O(n log n)` sweep:
/// sort by (x, y), then a point survives iff its y is strictly below every
/// strictly-smaller-x point's y, and it has the minimal y within its own
/// x-group (duplicates of that minimal (x, y) all survive).
pub fn pareto_frontier(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        cmp_f64(points[a].0, points[b].0).then(cmp_f64(points[a].1, points[b].1))
    });
    let mut out = Vec::new();
    let mut best_y = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        let x = points[idx[i]].0;
        let mut j = i;
        while j < idx.len() && points[idx[j]].0 == x {
            j += 1;
        }
        let group_min_y = points[idx[i]].1; // group is y-sorted
        if group_min_y < best_y {
            for &k in &idx[i..j] {
                if points[k].1 == group_min_y {
                    out.push(k);
                } else {
                    break;
                }
            }
            best_y = group_min_y;
        }
        i = j;
    }
    out.sort_unstable();
    out
}

/// `O(n^2)` brute-force reference — the correctness oracle the sweep (and
/// the server integration test) are checked against.
pub fn pareto_frontier_naive(points: &[(f64, f64)]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            points
                .iter()
                .enumerate()
                .all(|(j, &q)| j == i || !dominates(q, points[i]))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng64;

    #[test]
    fn dominance_relation() {
        assert!(dominates((1.0, 1.0), (2.0, 2.0)));
        assert!(dominates((1.0, 2.0), (1.0, 3.0)));
        assert!(!dominates((1.0, 2.0), (1.0, 2.0))); // equal: no strict edge
        assert!(!dominates((1.0, 3.0), (2.0, 2.0))); // incomparable
    }

    #[test]
    fn tiny_cases() {
        assert!(pareto_frontier(&[]).is_empty());
        assert_eq!(pareto_frontier(&[(3.0, 4.0)]), vec![0]);
        // a dominated point drops out
        assert_eq!(pareto_frontier(&[(1.0, 1.0), (2.0, 2.0)]), vec![0]);
        // incomparable points all stay
        assert_eq!(
            pareto_frontier(&[(1.0, 3.0), (2.0, 2.0), (3.0, 1.0)]),
            vec![0, 1, 2]
        );
    }

    #[test]
    fn duplicates_and_ties() {
        // exact duplicates of a frontier point all survive
        let pts = [(1.0, 1.0), (1.0, 1.0), (2.0, 0.5), (1.0, 2.0)];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2]);
        assert_eq!(pareto_frontier_naive(&pts), vec![0, 1, 2]);
        // same x, larger y is dominated; same y, larger x is dominated
        let pts = [(1.0, 1.0), (1.0, 1.5), (1.5, 1.0)];
        assert_eq!(pareto_frontier(&pts), vec![0]);
    }

    #[test]
    fn all_identical() {
        let pts = [(2.0, 2.0); 5];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sweep_matches_brute_force_random() {
        let mut rng = Rng64::new(0xADV1);
        for case in 0..50 {
            let n = 1 + (case % 40);
            // quantized coordinates force plenty of ties
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    (
                        (rng.range(0.0, 8.0)).floor(),
                        (rng.range(0.0, 8.0)).floor(),
                    )
                })
                .collect();
            assert_eq!(
                pareto_frontier(&pts),
                pareto_frontier_naive(&pts),
                "case {case}: {pts:?}"
            );
        }
    }
}
