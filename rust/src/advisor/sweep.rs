//! Sweep engine: evaluate a profiled workload across every (target
//! instance × batch size × pixel size × GPU count × purchase option)
//! candidate.
//!
//! Composition per target (paper Fig 11 "Predict", extended to a grid):
//!
//! 1. **Phase-1 (cross-instance)** — the anchor's min/max-batch profiles
//!    (and optionally min/max-pixel profiles) map to endpoint latencies on
//!    the target through the median ensemble. All endpoints for a target
//!    ride in ONE batched forest/MLP execution
//!    ([`CrossInstanceModel::predict_batch`]), consulted cache-first, so a
//!    full sweep is a handful of batched executions — not hundreds of
//!    scalar calls.
//! 2. **Phase-2 (interpolation)** — the target's batch polynomial
//!    denormalizes each candidate batch between the endpoint latencies
//!    (Eq. 1); candidate pixel sizes scale multiplicatively through the
//!    pixel polynomial relative to the profiled size.
//! 3. **Scenarios** — multi-GPU counts apply the Hafeez-style static
//!    multiplier ([`ScalingTable`]); each (candidate, GPU count) is priced
//!    on-demand and optionally spot ([`price_per_hour`]).
//!
//! [`CrossInstanceModel::predict_batch`]: crate::predictor::CrossInstanceModel::predict_batch

use super::cache::{CacheKey, CacheStats, PredictionCache, ProfileFingerprint};
use crate::gpu::Instance;
use crate::ml::FeatureMatrix;
use crate::predictor::{BatchPixelModel, Profet};
use crate::runtime::Runtime;
use crate::sim::cost_model::{price_per_hour, Pricing};
use crate::sim::multigpu::ScalingTable;
use crate::sim::workload::BATCHES;
use anyhow::Result;
use std::collections::BTreeMap;

/// Anchor-side profiles at the two endpoints of one scaling axis.
#[derive(Debug, Clone, PartialEq)]
pub struct EndpointProfiles {
    pub profile_min: BTreeMap<String, f64>,
    pub lat_min: f64,
    pub profile_max: BTreeMap<String, f64>,
    pub lat_max: f64,
}

/// One advisor query: what was profiled, and which candidate grid to sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRequest {
    pub anchor: Instance,
    /// Pixel size the batch-endpoint workloads were profiled at.
    pub pixels: usize,
    /// Anchor profiles at the min/max batch size (b=16 / b=256).
    pub batch: EndpointProfiles,
    /// Anchor profiles at the min/max pixel size (p=32 / p=256); required
    /// before `pixel_sizes` beyond the profiled size produce candidates.
    pub pixel: Option<EndpointProfiles>,
    /// Empty → the anchor plus every target with a trained model.
    pub targets: Vec<Instance>,
    /// Empty → the paper grid `[16, 32, 64, 128, 256]`.
    pub batches: Vec<usize>,
    /// Empty → just the profiled pixel size.
    pub pixel_sizes: Vec<usize>,
    /// Empty → single-GPU only.
    pub gpu_counts: Vec<usize>,
    pub include_spot: bool,
}

/// One scored deployment option.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub target: Instance,
    /// Global batch size (split across `n_gpus` when > 1).
    pub batch: usize,
    pub pixels: usize,
    pub n_gpus: usize,
    pub pricing: Pricing,
    /// Predicted per-step latency for the global batch, ms.
    pub latency_ms: f64,
    pub imgs_per_s: f64,
    pub price_hr: f64,
    pub cost_per_img_usd: f64,
}

impl Candidate {
    /// The Pareto objective pair — (seconds per image, $ per image), both
    /// minimized. Throughput-normalized so candidates at different batch
    /// sizes compare fairly.
    pub fn objectives(&self) -> (f64, f64) {
        (1.0 / self.imgs_per_s, self.cost_per_img_usd)
    }

    /// Deterministic total-order tiebreak for equal-score candidates.
    pub fn tie_key(&self) -> (&'static str, usize, usize, usize, &'static str) {
        (
            self.target.key(),
            self.batch,
            self.pixels,
            self.n_gpus,
            self.pricing.key(),
        )
    }
}

/// Deterministic presentation ranking shared by the serving layer and
/// in-process callers: cost-efficiency first, then speed, then the
/// stable tie key. Returns candidate indices in rank order.
pub fn rank_candidates(cands: &[Candidate]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..cands.len()).collect();
    order.sort_by(|&a, &b| {
        let (ca, cb) = (&cands[a], &cands[b]);
        crate::util::cmp_f64(ca.cost_per_img_usd, cb.cost_per_img_usd)
            .then(crate::util::cmp_f64(ca.objectives().0, cb.objectives().0))
            .then(ca.tie_key().cmp(&cb.tie_key()))
    });
    order
}

/// Endpoint latencies on one target, after phase-1.
struct TargetEndpoints {
    batch: (f64, f64),
    pixel: Option<(f64, f64)>,
}

/// Candidate grid shared by every target of one sweep.
struct Grid {
    batches: Vec<usize>,
    pixel_sizes: Vec<usize>,
    gpu_counts: Vec<usize>,
    include_spot: bool,
    profiled_pixels: usize,
}

/// Run the full sweep. Candidates come back unranked (the serving layer
/// sorts); targets without a trained cross/scale model are skipped.
///
/// `epoch` is the model-registry epoch `profet` was snapshotted at — it
/// becomes part of every phase-1 cache key so a sweep can never consume
/// (or produce) cache entries belonging to a different model generation.
/// In-process callers without a registry pass `0`.
pub fn sweep(
    rt: &Runtime,
    epoch: u64,
    profet: &Profet,
    cache: &PredictionCache,
    cache_stats: &CacheStats,
    scaling: &ScalingTable,
    req: &SweepRequest,
) -> Result<Vec<Candidate>> {
    anyhow::ensure!(
        req.batch.lat_min > 0.0 && req.batch.lat_max > 0.0,
        "anchor endpoint latencies must be positive"
    );
    if let Some(px) = &req.pixel {
        anyhow::ensure!(
            px.lat_min > 0.0 && px.lat_max > 0.0,
            "anchor pixel-endpoint latencies must be positive"
        );
    }
    // duplicate axis entries would mint duplicate candidates (and phantom
    // frontier points downstream) — every axis is deduplicated, sorted
    let sorted_dedup = |mut v: Vec<usize>| {
        v.sort_unstable();
        v.dedup();
        v
    };
    let mut targets: Vec<Instance> = if req.targets.is_empty() {
        let mut ts = vec![req.anchor];
        ts.extend(
            profet
                .cross
                .keys()
                .filter(|(a, _)| *a == req.anchor)
                .map(|(_, t)| *t),
        );
        ts
    } else {
        req.targets.clone()
    };
    targets.sort_unstable();
    targets.dedup();
    let grid = Grid {
        batches: sorted_dedup(if req.batches.is_empty() {
            BATCHES.to_vec()
        } else {
            req.batches.clone()
        }),
        pixel_sizes: sorted_dedup(if req.pixel_sizes.is_empty() {
            vec![req.pixels]
        } else {
            req.pixel_sizes.clone()
        }),
        gpu_counts: sorted_dedup(if req.gpu_counts.is_empty() {
            vec![1]
        } else {
            req.gpu_counts.clone()
        }),
        include_spot: req.include_spot,
        profiled_pixels: req.pixels,
    };

    // the pixel endpoints only matter when the grid actually asks for a
    // pixel size other than the profiled one — don't burn phase-1
    // executions on them otherwise
    let need_pixel = grid
        .pixel_sizes
        .iter()
        .any(|&p| p != grid.profiled_pixels);

    // canonicalize + fingerprint each endpoint profile ONCE; every
    // per-target cache key shares the byte stream
    let mut points: Vec<EndpointPoint> = vec![
        EndpointPoint::of(&req.batch.profile_min, req.batch.lat_min),
        EndpointPoint::of(&req.batch.profile_max, req.batch.lat_max),
    ];
    if need_pixel {
        if let Some(px) = &req.pixel {
            points.push(EndpointPoint::of(&px.profile_min, px.lat_min));
            points.push(EndpointPoint::of(&px.profile_max, px.lat_max));
        }
    }

    let mut out = Vec::new();
    for &target in &targets {
        let Some(scale) = profet.scale.get(&target) else {
            continue;
        };
        let Some(ep) =
            predict_endpoints(rt, epoch, profet, cache, cache_stats, req, target, &points)?
        else {
            continue; // no cross model for this (anchor, target)
        };
        expand_candidates(target, scale, &ep, scaling, &grid, &mut out);
    }
    Ok(out)
}

/// One anchor-side endpoint observation with its precomputed fingerprint.
struct EndpointPoint<'a> {
    profile: &'a BTreeMap<String, f64>,
    lat: f64,
    pf: ProfileFingerprint,
}

impl<'a> EndpointPoint<'a> {
    fn of(profile: &'a BTreeMap<String, f64>, lat: f64) -> EndpointPoint<'a> {
        EndpointPoint {
            profile,
            lat,
            pf: ProfileFingerprint::of(profile),
        }
    }
}

/// Phase-1: endpoint latencies on `target`. Identity for the anchor
/// itself; one cache-first batched ensemble execution otherwise.
/// `points` is [batch_min, batch_max] or [batch_min, batch_max,
/// pixel_min, pixel_max].
#[allow(clippy::too_many_arguments)]
fn predict_endpoints(
    rt: &Runtime,
    epoch: u64,
    profet: &Profet,
    cache: &PredictionCache,
    cache_stats: &CacheStats,
    req: &SweepRequest,
    target: Instance,
    points: &[EndpointPoint<'_>],
) -> Result<Option<TargetEndpoints>> {
    if target == req.anchor {
        return Ok(Some(TargetEndpoints {
            batch: (req.batch.lat_min, req.batch.lat_max),
            pixel: req.pixel.as_ref().map(|p| (p.lat_min, p.lat_max)),
        }));
    }
    let Some(model) = profet.cross.get(&(req.anchor, target)) else {
        return Ok(None);
    };
    let mut vals: Vec<Option<f64>> = vec![None; points.len()];
    let mut miss_idx: Vec<usize> = Vec::new();
    let mut miss_keys: Vec<CacheKey> = Vec::new();
    for (i, point) in points.iter().enumerate() {
        let key = CacheKey::keyed(epoch, req.anchor, target, point.lat, &point.pf);
        match cache.get(&key, cache_stats) {
            Some((v, _)) => vals[i] = Some(v),
            None => {
                miss_idx.push(i);
                miss_keys.push(key);
            }
        }
    }
    if !miss_idx.is_empty() {
        let rows: Vec<Vec<f64>> = miss_idx
            .iter()
            .map(|&i| profet.feature_space.vectorize(points[i].profile))
            .collect();
        let lats: Vec<f64> = miss_idx.iter().map(|&i| points[i].lat).collect();
        let preds = model.predict_batch(rt, &FeatureMatrix::from_rows(&rows)?, &lats)?;
        for ((&i, key), pred) in miss_idx.iter().zip(miss_keys).zip(preds) {
            cache.insert(key, pred);
            vals[i] = Some(pred.0);
        }
    }
    Ok(Some(TargetEndpoints {
        batch: (vals[0].unwrap(), vals[1].unwrap()),
        pixel: if points.len() == 4 {
            Some((vals[2].unwrap(), vals[3].unwrap()))
        } else {
            None
        },
    }))
}

/// Phase-2 + scenarios: expand one target's endpoint latencies over the
/// candidate grid. Non-finite / non-positive interpolations and
/// infeasible GPU counts are skipped, never emitted.
fn expand_candidates(
    target: Instance,
    scale: &BatchPixelModel,
    ep: &TargetEndpoints,
    scaling: &ScalingTable,
    grid: &Grid,
    out: &mut Vec<Candidate>,
) {
    let (t_bmin, t_bmax) = ep.batch;
    if !(t_bmin.is_finite() && t_bmax.is_finite() && t_bmin > 0.0 && t_bmax > 0.0) {
        return;
    }
    // pixel scaling curve, multiplicative relative to the profiled size
    let pixel_ratio = |p: usize| -> Option<f64> {
        if p == grid.profiled_pixels {
            return Some(1.0);
        }
        let (t_pmin, t_pmax) = ep.pixel?;
        let base = scale.predict_pixels(grid.profiled_pixels, t_pmin, t_pmax);
        let at = scale.predict_pixels(p, t_pmin, t_pmax);
        (base.is_finite() && at.is_finite() && base > 0.0 && at > 0.0).then(|| at / base)
    };
    for &b in &grid.batches {
        let lat_b = scale.predict_batch(b, t_bmin, t_bmax);
        if !(lat_b.is_finite() && lat_b > 0.0) {
            continue;
        }
        for &p in &grid.pixel_sizes {
            let Some(ratio) = pixel_ratio(p) else {
                continue;
            };
            let lat_1gpu = lat_b * ratio;
            for &n in &grid.gpu_counts {
                // mirror the simulator's executability rule
                // (multi_gpu_latency): the global batch must split evenly
                // into non-empty per-GPU shards
                if n == 0 || b % n != 0 || b / n == 0 {
                    continue;
                }
                let Some(mult) = scaling.multiplier(target, n) else {
                    continue;
                };
                let latency_ms = lat_1gpu * mult;
                if !(latency_ms.is_finite() && latency_ms > 0.0) {
                    continue;
                }
                let imgs_per_s = b as f64 * 1e3 / latency_ms;
                for pricing in Pricing::ALL {
                    if pricing == Pricing::Spot && !grid.include_spot {
                        continue;
                    }
                    let price_hr = price_per_hour(target, pricing, n);
                    out.push(Candidate {
                        target,
                        batch: b,
                        pixels: p,
                        n_gpus: n,
                        pricing,
                        latency_ms,
                        imgs_per_s,
                        price_hr,
                        cost_per_img_usd: price_hr / 3600.0 / imgs_per_s,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::PolyRegression;

    /// Linear T_N curve: batch/pixel interpolation behaves like the ideal
    /// normalized ramp, so endpoint predictions are easy to reason about.
    fn linear_scale_model(instance: Instance) -> BatchPixelModel {
        let xs = [0.0, 0.25, 0.5, 0.75, 1.0];
        let poly = PolyRegression::fit(&xs, &xs, 2).unwrap();
        BatchPixelModel {
            instance,
            batch_poly: poly.clone(),
            pixel_poly: poly,
            order: 2,
        }
    }

    fn grid(batches: &[usize], pixel_sizes: &[usize], gpus: &[usize], spot: bool) -> Grid {
        Grid {
            batches: batches.to_vec(),
            pixel_sizes: pixel_sizes.to_vec(),
            gpu_counts: gpus.to_vec(),
            include_spot: spot,
            profiled_pixels: 64,
        }
    }

    #[test]
    fn expand_covers_the_grid() {
        let scale = linear_scale_model(Instance::P3);
        let ep = TargetEndpoints {
            batch: (100.0, 900.0),
            pixel: None,
        };
        let mut out = Vec::new();
        expand_candidates(
            Instance::P3,
            &scale,
            &ep,
            &ScalingTable::new(),
            &grid(&[16, 64, 256], &[64], &[1], true),
            &mut out,
        );
        // 3 batches x 1 pixel x 1 gpu x 2 pricing options
        assert_eq!(out.len(), 6);
        // endpoints recover the endpoint latencies through the linear poly
        let b16 = out.iter().find(|c| c.batch == 16 && c.pricing == Pricing::OnDemand).unwrap();
        let b256 = out.iter().find(|c| c.batch == 256 && c.pricing == Pricing::OnDemand).unwrap();
        assert!((b16.latency_ms - 100.0).abs() < 1e-6, "{}", b16.latency_ms);
        assert!((b256.latency_ms - 900.0).abs() < 1e-6, "{}", b256.latency_ms);
        // spot rides the same latency at a lower price
        let b16_spot = out.iter().find(|c| c.batch == 16 && c.pricing == Pricing::Spot).unwrap();
        assert_eq!(b16_spot.latency_ms, b16.latency_ms);
        assert!(b16_spot.price_hr < b16.price_hr);
        // throughput/cost identities
        assert!((b16.imgs_per_s - 16.0 * 1e3 / 100.0).abs() < 1e-9);
        assert!(
            (b16.cost_per_img_usd - b16.price_hr / 3600.0 / b16.imgs_per_s).abs() < 1e-15
        );
    }

    #[test]
    fn pixel_sizes_need_pixel_endpoints() {
        let scale = linear_scale_model(Instance::P3);
        let ep = TargetEndpoints {
            batch: (100.0, 900.0),
            pixel: None,
        };
        let mut out = Vec::new();
        expand_candidates(
            Instance::P3,
            &scale,
            &ep,
            &ScalingTable::new(),
            &grid(&[64], &[64, 128], &[1], false),
            &mut out,
        );
        // p=128 has no pixel endpoints -> only the profiled size survives
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].pixels, 64);

        // with endpoints, the 128px candidate appears and is slower
        let ep = TargetEndpoints {
            batch: (100.0, 900.0),
            pixel: Some((50.0, 1000.0)),
        };
        let mut out = Vec::new();
        expand_candidates(
            Instance::P3,
            &scale,
            &ep,
            &ScalingTable::new(),
            &grid(&[64], &[64, 128], &[1], false),
            &mut out,
        );
        assert_eq!(out.len(), 2);
        let p64 = out.iter().find(|c| c.pixels == 64).unwrap();
        let p128 = out.iter().find(|c| c.pixels == 128).unwrap();
        assert!(p128.latency_ms > p64.latency_ms);
    }

    #[test]
    fn multi_gpu_scenarios_scale_latency_and_price() {
        let scale = linear_scale_model(Instance::P3);
        let ep = TargetEndpoints {
            batch: (100.0, 900.0),
            pixel: None,
        };
        let scaling = ScalingTable::new();
        let mut out = Vec::new();
        expand_candidates(
            Instance::P3,
            &scale,
            &ep,
            &scaling,
            &grid(&[128], &[64], &[1, 2], false),
            &mut out,
        );
        assert_eq!(out.len(), 2);
        let g1 = out.iter().find(|c| c.n_gpus == 1).unwrap();
        let g2 = out.iter().find(|c| c.n_gpus == 2).unwrap();
        // the 2-GPU step latency is exactly the 1-GPU latency times the
        // calibrated static multiplier, at double the hourly price
        let mult = scaling.multiplier(Instance::P3, 2).unwrap();
        assert!((g2.latency_ms - g1.latency_ms * mult).abs() < 1e-9 * g1.latency_ms);
        assert_eq!(g2.price_hr, 2.0 * g1.price_hr);
    }

    #[test]
    fn indivisible_or_empty_shards_are_skipped() {
        let scale = linear_scale_model(Instance::P3);
        let ep = TargetEndpoints {
            batch: (100.0, 900.0),
            pixel: None,
        };
        let mut out = Vec::new();
        // b=16 on 3 GPUs (16 % 3 != 0) and on 64 GPUs (shard would be 0):
        // both rejected, exactly like sim::multigpu::multi_gpu_latency
        expand_candidates(
            Instance::P3,
            &scale,
            &ep,
            &ScalingTable::new(),
            &grid(&[16], &[64], &[1, 3, 64], false),
            &mut out,
        );
        assert!(out.iter().all(|c| c.n_gpus == 1), "{out:?}");
        // b=128 on 4 GPUs is executable and present
        let mut out = Vec::new();
        expand_candidates(
            Instance::P3,
            &scale,
            &ep,
            &ScalingTable::new(),
            &grid(&[128], &[64], &[4], false),
            &mut out,
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].n_gpus, 4);
    }

    #[test]
    fn degenerate_endpoints_emit_nothing() {
        let scale = linear_scale_model(Instance::P3);
        let mut out = Vec::new();
        for bad in [
            (f64::NAN, 900.0),
            (100.0, f64::INFINITY),
            (-5.0, 900.0),
            (0.0, 900.0),
        ] {
            expand_candidates(
                Instance::P3,
                &scale,
                &TargetEndpoints { batch: bad, pixel: None },
                &ScalingTable::new(),
                &grid(&[64], &[64], &[1], false),
                &mut out,
            );
        }
        assert!(out.is_empty());
    }
}
