//! DNN ensemble member: the rust-side trainer/driver for the AOT-compiled
//! JAX train step (paper Sec III-C1: 128·64·32·16·1 dense, ReLU, Adam
//! lr 1e-3, MAPE+RMSE loss).
//!
//! Feature preprocessing lives here (log1p on per-op milliseconds, targets
//! scaled to seconds) so the HLO artifacts stay plain: the same transform
//! is applied at train and predict time and round-trips through JSON
//! persistence.

use crate::ml::FeatureMatrix;
use crate::runtime::{MlpState, Runtime};
use crate::util::{Json, Rng64};
use anyhow::{anyhow, Result};

/// Target scale: train in seconds (keeps the RMSE term O(1)).
const Y_SCALE: f64 = 1000.0;

/// Trained DNN regressor (flat params + the preprocessing contract).
#[derive(Debug, Clone)]
pub struct DnnRegressor {
    pub params: Vec<f32>,
    pub d_feat: usize,
    /// Training-loss trace (one entry per epoch) for diagnostics.
    pub loss_trace: Vec<f64>,
}

/// Training hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    pub epochs: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 60,
            seed: 0xD99,
        }
    }
}

fn preprocess(v: f64) -> f32 {
    (v.max(0.0)).ln_1p() as f32
}

impl DnnRegressor {
    /// Train on the columnar matrix `x` (width `runtime.meta.d_feat`)
    /// against latencies `y` (ms), driving the HLO train-step artifact.
    pub fn fit(
        rt: &Runtime,
        x: &FeatureMatrix,
        y: &[f64],
        cfg: TrainConfig,
    ) -> Result<DnnRegressor> {
        let meta = &rt.meta;
        anyhow::ensure!(!x.is_empty() && x.n_rows() == y.len(), "bad shapes");
        anyhow::ensure!(
            x.n_cols() == meta.d_feat,
            "feature width != artifact d_feat {}",
            meta.d_feat
        );
        let n = x.n_rows();
        let d = meta.d_feat;
        // flat row-major preprocessed copy: minibatch assembly below is one
        // contiguous memcpy per row
        let mut xs = vec![0f32; n * d];
        for j in 0..d {
            for (i, &v) in x.col(j).iter().enumerate() {
                xs[i * d + j] = preprocess(v);
            }
        }
        let ys: Vec<f32> = y.iter().map(|v| (v / Y_SCALE) as f32).collect();

        let mut state = MlpState::init(meta.d_feat, cfg.seed);
        let mut rng = Rng64::new(cfg.seed ^ 0xABCD);
        let b = meta.b_train;
        let mut order: Vec<usize> = (0..n).collect();
        let mut xbuf = vec![0f32; b * meta.d_feat];
        let mut ybuf = vec![0f32; b];
        let mut loss_trace = Vec::with_capacity(cfg.epochs);

        for _epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0;
            let mut steps = 0usize;
            for chunk in order.chunks(b) {
                // pad short tails by repeating earlier rows (keeps the
                // fixed artifact shape; slight oversampling is harmless)
                for (slot, &src) in chunk.iter().chain(order.iter()).take(b).enumerate() {
                    xbuf[slot * d..(slot + 1) * d].copy_from_slice(&xs[src * d..(src + 1) * d]);
                    ybuf[slot] = ys[src];
                }
                let loss = rt.train_step(&mut state, &xbuf, &ybuf)?;
                anyhow::ensure!(loss.is_finite(), "diverged (loss={loss})");
                epoch_loss += loss as f64;
                steps += 1;
            }
            loss_trace.push(epoch_loss / steps.max(1) as f64);
        }

        Ok(DnnRegressor {
            params: state.params,
            d_feat: meta.d_feat,
            loss_trace,
        })
    }

    /// Predict latencies (ms) for the matrix rows, chunked through the
    /// fixed `b_pred` forward artifact.
    pub fn predict(&self, rt: &Runtime, x: &FeatureMatrix) -> Result<Vec<f64>> {
        let meta = &rt.meta;
        anyhow::ensure!(self.d_feat == meta.d_feat, "artifact mismatch");
        if x.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(x.n_cols() == meta.d_feat, "row width");
        let n = x.n_rows();
        let d = meta.d_feat;
        let b = meta.b_pred;
        let mut out = Vec::with_capacity(n);
        let mut buf = vec![0f32; b * d];
        let mut start = 0;
        while start < n {
            let rows = (n - start).min(b);
            for slot in 0..rows {
                let i = start + slot;
                for j in 0..d {
                    buf[slot * d + j] = preprocess(x.get(i, j));
                }
            }
            // zero any tail slots
            for slot in rows..b {
                buf[slot * d..(slot + 1) * d].fill(0.0);
            }
            let yhat = rt.mlp_forward(&self.params, &buf)?;
            out.extend(yhat[..rows].iter().map(|v| (*v as f64) * Y_SCALE));
            start += rows;
        }
        Ok(out)
    }

    pub fn predict_one(&self, rt: &Runtime, x: &[f64]) -> Result<f64> {
        let m = FeatureMatrix::from_rows(std::slice::from_ref(&x.to_vec()))?;
        Ok(self.predict(rt, &m)?[0])
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "params",
            Json::from_f64s(&self.params.iter().map(|p| *p as f64).collect::<Vec<_>>()),
        );
        o.set("d_feat", Json::Num(self.d_feat as f64));
        o.set("loss_trace", Json::from_f64s(&self.loss_trace));
        o
    }

    pub fn from_json(j: &Json) -> Result<DnnRegressor> {
        Ok(DnnRegressor {
            params: j
                .get("params")
                .ok_or_else(|| anyhow!("params"))?
                .to_f64s()?
                .into_iter()
                .map(|v| v as f32)
                .collect(),
            d_feat: j.req_usize("d_feat")?,
            loss_trace: j
                .get("loss_trace")
                .map(|t| t.to_f64s())
                .transpose()?
                .unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime;

    /// End-to-end: the HLO-driven trainer learns a synthetic latency-like
    /// function. (Integration-grade test; needs `make artifacts` and the
    /// PJRT backend — skipped when neither is available.)
    #[test]
    fn fit_and_predict_synthetic() {
        let Ok(rt) = runtime::load_default() else {
            eprintln!("skipping fit_and_predict_synthetic: artifacts/PJRT unavailable");
            return;
        };
        let d = rt.meta.d_feat;
        let mut rng = Rng64::new(77);
        // synthetic "profiles": positive ms values; target = weighted sum
        let w: Vec<f64> = (0..d).map(|_| rng.range(0.5, 2.0)).collect();
        let make = |rng: &mut Rng64| -> (Vec<f64>, f64) {
            let x: Vec<f64> = (0..d).map(|_| rng.range(0.0, 50.0)).collect();
            let y: f64 = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>() + 20.0;
            (x, y)
        };
        let (xs, ys): (Vec<_>, Vec<_>) = (0..256).map(|_| make(&mut rng)).unzip();
        let xm = FeatureMatrix::from_rows(&xs).unwrap();
        let model = DnnRegressor::fit(
            &rt,
            &xm,
            &ys,
            TrainConfig {
                epochs: 40,
                seed: 1,
            },
        )
        .unwrap();
        // loss decreased
        assert!(model.loss_trace.last().unwrap() < &(model.loss_trace[0] * 0.7));
        // holdout MAPE sane (< 40% on this easy function)
        let (xt, yt): (Vec<_>, Vec<_>) = (0..64).map(|_| make(&mut rng)).unzip();
        let xtm = FeatureMatrix::from_rows(&xt).unwrap();
        let pred = model.predict(&rt, &xtm).unwrap();
        let mape = crate::ml::metrics::mape(&yt, &pred);
        assert!(mape < 40.0, "holdout mape {mape}");
        // persistence preserves predictions
        let j = Json::parse(&model.to_json().to_string()).unwrap();
        let model2 = DnnRegressor::from_json(&j).unwrap();
        let pred2 = model2.predict(&rt, &xtm).unwrap();
        for (a, b) in pred.iter().zip(&pred2) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
