//! DNN ensemble member: the rust-side trainer/driver for the AOT-compiled
//! JAX train step (paper Sec III-C1: 128·64·32·16·1 dense, ReLU, Adam
//! lr 1e-3, MAPE+RMSE loss).
//!
//! Feature preprocessing lives here (log1p on per-op milliseconds, targets
//! scaled to seconds) so the HLO artifacts stay plain: the same transform
//! is applied at train and predict time and round-trips through JSON
//! persistence.

use crate::runtime::{MlpState, Runtime};
use crate::util::{Json, Rng64};
use anyhow::{anyhow, Result};

/// Target scale: train in seconds (keeps the RMSE term O(1)).
const Y_SCALE: f64 = 1000.0;

/// Trained DNN regressor (flat params + the preprocessing contract).
#[derive(Debug, Clone)]
pub struct DnnRegressor {
    pub params: Vec<f32>,
    pub d_feat: usize,
    /// Training-loss trace (one entry per epoch) for diagnostics.
    pub loss_trace: Vec<f64>,
}

/// Training hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    pub epochs: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 60,
            seed: 0xD99,
        }
    }
}

fn preprocess_x(row: &[f64]) -> Vec<f32> {
    row.iter().map(|v| (v.max(0.0)).ln_1p() as f32).collect()
}

impl DnnRegressor {
    /// Train on rows `x` (feature vectors of width `runtime.meta.d_feat`)
    /// against latencies `y` (ms), driving the HLO train-step artifact.
    pub fn fit(rt: &Runtime, x: &[Vec<f64>], y: &[f64], cfg: TrainConfig) -> Result<DnnRegressor> {
        let meta = &rt.meta;
        anyhow::ensure!(!x.is_empty() && x.len() == y.len(), "bad shapes");
        anyhow::ensure!(
            x.iter().all(|r| r.len() == meta.d_feat),
            "feature width != artifact d_feat {}",
            meta.d_feat
        );
        let xs: Vec<Vec<f32>> = x.iter().map(|r| preprocess_x(r)).collect();
        let ys: Vec<f32> = y.iter().map(|v| (v / Y_SCALE) as f32).collect();

        let mut state = MlpState::init(meta.d_feat, cfg.seed);
        let mut rng = Rng64::new(cfg.seed ^ 0xABCD);
        let n = xs.len();
        let b = meta.b_train;
        let mut order: Vec<usize> = (0..n).collect();
        let mut xbuf = vec![0f32; b * meta.d_feat];
        let mut ybuf = vec![0f32; b];
        let mut loss_trace = Vec::with_capacity(cfg.epochs);

        for _epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0;
            let mut steps = 0usize;
            for chunk in order.chunks(b) {
                // pad short tails by repeating earlier rows (keeps the
                // fixed artifact shape; slight oversampling is harmless)
                for (slot, &src) in chunk.iter().chain(order.iter()).take(b).enumerate() {
                    xbuf[slot * meta.d_feat..(slot + 1) * meta.d_feat]
                        .copy_from_slice(&xs[src]);
                    ybuf[slot] = ys[src];
                }
                let loss = rt.train_step(&mut state, &xbuf, &ybuf)?;
                anyhow::ensure!(loss.is_finite(), "diverged (loss={loss})");
                epoch_loss += loss as f64;
                steps += 1;
            }
            loss_trace.push(epoch_loss / steps.max(1) as f64);
        }

        Ok(DnnRegressor {
            params: state.params,
            d_feat: meta.d_feat,
            loss_trace,
        })
    }

    /// Predict latencies (ms) for feature rows, chunked through the fixed
    /// `b_pred` forward artifact.
    pub fn predict(&self, rt: &Runtime, x: &[Vec<f64>]) -> Result<Vec<f64>> {
        let meta = &rt.meta;
        anyhow::ensure!(self.d_feat == meta.d_feat, "artifact mismatch");
        let b = meta.b_pred;
        let mut out = Vec::with_capacity(x.len());
        let mut buf = vec![0f32; b * meta.d_feat];
        for chunk in x.chunks(b) {
            for (slot, row) in chunk.iter().enumerate() {
                anyhow::ensure!(row.len() == meta.d_feat, "row width");
                let p = preprocess_x(row);
                buf[slot * meta.d_feat..(slot + 1) * meta.d_feat].copy_from_slice(&p);
            }
            // zero any tail slots
            for slot in chunk.len()..b {
                buf[slot * meta.d_feat..(slot + 1) * meta.d_feat].fill(0.0);
            }
            let yhat = rt.mlp_forward(&self.params, &buf)?;
            out.extend(
                yhat[..chunk.len()]
                    .iter()
                    .map(|v| (*v as f64) * Y_SCALE),
            );
        }
        Ok(out)
    }

    pub fn predict_one(&self, rt: &Runtime, x: &[f64]) -> Result<f64> {
        Ok(self.predict(rt, std::slice::from_ref(&x.to_vec()))?[0])
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "params",
            Json::from_f64s(&self.params.iter().map(|p| *p as f64).collect::<Vec<_>>()),
        );
        o.set("d_feat", Json::Num(self.d_feat as f64));
        o.set("loss_trace", Json::from_f64s(&self.loss_trace));
        o
    }

    pub fn from_json(j: &Json) -> Result<DnnRegressor> {
        Ok(DnnRegressor {
            params: j
                .get("params")
                .ok_or_else(|| anyhow!("params"))?
                .to_f64s()?
                .into_iter()
                .map(|v| v as f32)
                .collect(),
            d_feat: j.req_usize("d_feat")?,
            loss_trace: j
                .get("loss_trace")
                .map(|t| t.to_f64s())
                .transpose()?
                .unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime;

    /// End-to-end: the HLO-driven trainer learns a synthetic latency-like
    /// function. (Integration-grade test; needs `make artifacts`.)
    #[test]
    fn fit_and_predict_synthetic() {
        let rt = runtime::load_default().expect("make artifacts first");
        let d = rt.meta.d_feat;
        let mut rng = Rng64::new(77);
        // synthetic "profiles": positive ms values; target = weighted sum
        let w: Vec<f64> = (0..d).map(|_| rng.range(0.5, 2.0)).collect();
        let make = |rng: &mut Rng64| -> (Vec<f64>, f64) {
            let x: Vec<f64> = (0..d).map(|_| rng.range(0.0, 50.0)).collect();
            let y: f64 = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>() + 20.0;
            (x, y)
        };
        let (xs, ys): (Vec<_>, Vec<_>) = (0..256).map(|_| make(&mut rng)).unzip();
        let model = DnnRegressor::fit(
            &rt,
            &xs,
            &ys,
            TrainConfig {
                epochs: 40,
                seed: 1,
            },
        )
        .unwrap();
        // loss decreased
        assert!(model.loss_trace.last().unwrap() < &(model.loss_trace[0] * 0.7));
        // holdout MAPE sane (< 40% on this easy function)
        let (xt, yt): (Vec<_>, Vec<_>) = (0..64).map(|_| make(&mut rng)).unzip();
        let pred = model.predict(&rt, &xt).unwrap();
        let mape = crate::ml::metrics::mape(&yt, &pred);
        assert!(mape < 40.0, "holdout mape {mape}");
        // persistence preserves predictions
        let j = Json::parse(&model.to_json().to_string()).unwrap();
        let model2 = DnnRegressor::from_json(&j).unwrap();
        let pred2 = model2.predict(&rt, &xt).unwrap();
        for (a, b) in pred.iter().zip(&pred2) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
