//! Evaluation harness: regenerates every table and figure of the paper's
//! evaluation section (the DESIGN.md experiment index).
//!
//! Each `fig_*` / `table_*` function returns a plain-text report with the
//! same rows/series the paper presents; [`run`] dispatches by experiment
//! id (any entry of [`ALL_EXPERIMENTS`], or `"all"`) over a shared
//! [`Ctx`] that trains the system once and reuses it across experiments.
//! Shape assertions (who wins, where the crossovers are) are emitted as
//! CHECK lines so `repro eval` output documents whether the reproduction
//! holds. Driven by `repro eval --exp <id> [--out report.txt]`.

mod ablations;
mod context;
mod extensions;
mod figures;
mod tables;

pub use context::{Ctx, SPLIT_SEED};

use anyhow::Result;

/// All experiment ids in paper order, then the ablations of the paper's
/// stated-but-unshown empirical choices, then the Sec VII (Discussion)
/// extensions.
pub const ALL_EXPERIMENTS: [&str; 20] = [
    "table1", "fig2a", "fig2b", "fig2c", "fig9", "fig10", "fig11", "fig12", "fig13", "table2",
    "table3", "table4", "table5", "table6", "abl_cut", "abl_linkage", "abl_ensemble",
    "ext_multigpu", "ext_sdk", "ext_transformer",
];

/// Run one experiment (or "all") and return the textual report.
pub fn run(exp: &str, ctx: &mut Ctx) -> Result<String> {
    Ok(match exp {
        "table1" => tables::table1(),
        "fig2a" => figures::fig2a(),
        "fig2b" => figures::fig2b(),
        "fig2c" => figures::fig2c(),
        "fig9" => figures::fig9(ctx)?,
        "fig10" => figures::fig10(ctx)?,
        "fig11" => figures::fig11(ctx)?,
        "fig12" => figures::fig12(ctx)?,
        "fig13" => figures::fig13(ctx)?,
        "table2" => tables::table2(ctx)?,
        "table3" => tables::table3(ctx)?,
        "table4" => tables::table4(ctx)?,
        "table5" => tables::table5(ctx)?,
        "table6" => tables::table6(ctx)?,
        "abl_cut" => ablations::abl_cut_height(ctx)?,
        "abl_linkage" => ablations::abl_linkage(ctx)?,
        "abl_ensemble" => ablations::abl_ensemble(ctx)?,
        "ext_multigpu" => extensions::ext_multigpu(ctx)?,
        "ext_sdk" => extensions::ext_sdk(ctx)?,
        "ext_transformer" => extensions::ext_transformer(ctx)?,
        "all" => {
            let mut out = String::new();
            for e in ALL_EXPERIMENTS {
                out.push_str(&run(e, ctx)?);
                out.push('\n');
            }
            out
        }
        other => anyhow::bail!("unknown experiment `{other}` (use one of {ALL_EXPERIMENTS:?} or `all`)"),
    })
}

/// Format a CHECK line: a paper-shape assertion evaluated on our numbers.
pub(crate) fn check(label: &str, ok: bool) -> String {
    format!("  CHECK [{}] {label}\n", if ok { "PASS" } else { "FAIL" })
}
