//! Shared evaluation context: the corpus, the main trained PROFET system,
//! and the train/test split reused across experiments.

use crate::data::Corpus;
use crate::gpu::Instance;
use crate::predictor::{Profet, TrainOptions};
use crate::runtime::{self, Runtime};
use anyhow::Result;

/// Evaluation split seed (fixed for reproducibility of the whole paper
/// reproduction; see EXPERIMENTS.md).
pub const SPLIT_SEED: u64 = 20220707;

/// Holds everything the experiments reuse. Heavy pieces (the main PROFET
/// training) are built lazily on first use.
pub struct Ctx {
    pub rt: Runtime,
    /// Corpus over all six instances (core experiments filter to CORE).
    pub corpus: Corpus,
    pub train_idx: Vec<usize>,
    pub test_idx: Vec<usize>,
    pub(crate) profet: Option<Profet>,
    /// Reduced training effort (tests / quick runs): fewer trees + epochs.
    pub fast: bool,
}

impl Ctx {
    /// Build the context: generate the corpus and the 80/20 split.
    pub fn build() -> Result<Ctx> {
        let rt = runtime::load_default()?;
        let corpus = Corpus::generate(&Instance::ALL);
        let (train_idx, test_idx) = corpus.split_random(0.2, SPLIT_SEED);
        let fast = std::env::var("REPRO_FAST").is_ok();
        Ok(Ctx {
            rt,
            corpus,
            train_idx,
            test_idx,
            profet: None,
            fast,
        })
    }

    /// Training options honouring fast mode.
    pub fn train_opts(&self) -> TrainOptions {
        let mut o = TrainOptions::default();
        if self.fast {
            o.n_trees = 25;
            o.dnn_epochs = 15;
        }
        o
    }

    /// The main PROFET system: anchors/targets = the four core instances,
    /// clustering on, order-2 polynomials, trained on the 80% split.
    pub fn profet(&mut self) -> Result<&Profet> {
        if self.profet.is_none() {
            let opts = self.train_opts();
            let p = Profet::train(&self.rt, &self.corpus, &self.train_idx, &opts)?;
            self.profet = Some(p);
        }
        Ok(self.profet.as_ref().unwrap())
    }
}
