//! Sec VII (Discussion) extensions, implemented rather than deferred:
//! multi-GPU latency prediction via static multipliers, SDK-version
//! sensitivity, and non-CNN (transformer) prediction.

use super::{check, Ctx};
use crate::gpu::Instance;
use crate::ml::metrics;
use crate::models::ModelId;
use crate::predictor::{Profet, TrainOptions};
use crate::sim::{self, multigpu, SdkVersion, Workload};
use anyhow::Result;
use std::fmt::Write as _;

/// Multi-GPU: PROFET 1-GPU prediction x Hafeez static multiplier vs the
/// simulated multi-GPU ground truth.
pub fn ext_multigpu(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let mut out = String::from(
        "== Extension (Sec VII): multi-GPU prediction via static multiplier ==\n",
    );
    // calibration models measure the per-(instance, N) multiplier;
    // evaluation models are disjoint.
    let calibration: Vec<(ModelId, usize, usize)> = vec![
        (ModelId::ResNet18, 128, 64),
        (ModelId::Vgg11, 128, 64),
        (ModelId::MobileNetV2, 128, 64),
        (ModelId::Cifar10Cnn, 128, 64),
    ];
    let eval_models = [ModelId::ResNet50, ModelId::Vgg16, ModelId::InceptionV3];
    let anchor = Instance::G4dn;

    let mut all_apes = Vec::new();
    for target in [Instance::P3, Instance::G3s] {
        for n in [2usize, 4] {
            let Some(mult) = multigpu::static_multiplier(target, n, &calibration) else {
                continue;
            };
            let mut apes = Vec::new();
            for m in eval_models {
                for p in [64usize, 128] {
                    let global_batch = 128usize;
                    let Some(truth) = multigpu::multi_gpu_latency(m, global_batch, p, target, n)
                    else {
                        continue;
                    };
                    // PROFET predicts the 1-GPU latency from an anchor profile
                    let w1 = Workload::new(m, global_batch, p);
                    let Some(run_a) = sim::run_workload(&w1, anchor) else {
                        continue;
                    };
                    let (p1, _) = profet.predict_cross(
                        &ctx.rt,
                        anchor,
                        target,
                        &run_a.profile.aggregated(),
                        run_a.latency_ms,
                    )?;
                    let pred = p1 * mult;
                    apes.push(100.0 * (pred - truth).abs() / truth);
                }
            }
            let mape = crate::util::mean(&apes);
            all_apes.push(mape);
            let _ = writeln!(
                out,
                "  {:5} x{n} GPUs  multiplier={mult:5.3}  MAPE={mape:6.2}%  (n={})",
                target.key(),
                apes.len()
            );
        }
    }
    // the static multiplier is deliberately coarse (one scalar per
    // (instance, N)); Hafeez et al. report it works because scaling ratios
    // are "more static" than cross-instance behaviour — under 40% MAPE
    // without ever running the eval models on multiple GPUs.
    out.push_str(&check(
        "static-multiplier multi-GPU prediction lands under 40% MAPE",
        all_apes.iter().all(|&m| m < 40.0),
    ));
    Ok(out)
}

/// SDK-version sensitivity: models trained on TF2.3 degrade on TF2.7
/// measurements; recalibrating on the new stack recovers accuracy.
pub fn ext_sdk(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet23 = ctx.profet.as_ref().unwrap();
    let mut out = String::from("== Extension (Sec VII): SDK version sensitivity ==\n");
    let anchor = Instance::G4dn;
    let target = Instance::P3;

    // evaluate the TF2.3-trained model against both stacks
    let mut mape_same = Vec::new(); // TF2.3 profile -> TF2.3 truth
    let mut mape_skew = Vec::new(); // TF2.7 profile -> TF2.7 truth, TF2.3 model
    let test_idx = ctx.test_idx.clone();
    for &i in &test_idx {
        let e = &ctx.corpus.entries[i];
        let w = e.workload;
        let (Some(a23), Some(t23)) = (e.runs.get(&anchor), e.runs.get(&target)) else {
            continue;
        };
        let (p, _) = profet23.predict_cross(&ctx.rt, anchor, target, &a23.profile, a23.latency_ms)?;
        mape_same.push(100.0 * (p - t23.latency_ms).abs() / t23.latency_ms);

        let (Some(a27), Some(t27)) = (
            sim::workload::run_workload_sdk(&w, anchor, SdkVersion::Tf27),
            sim::workload::run_workload_sdk(&w, target, SdkVersion::Tf27),
        ) else {
            continue;
        };
        let (p, _) = profet23.predict_cross(
            &ctx.rt,
            anchor,
            target,
            &a27.profile.aggregated(),
            a27.latency_ms,
        )?;
        mape_skew.push(100.0 * (p - t27.latency_ms).abs() / t27.latency_ms);
    }
    let same = crate::util::mean(&mape_same);
    let skew = crate::util::mean(&mape_skew);
    let _ = writeln!(out, "  TF2.3 model on TF2.3 measurements: MAPE={same:6.2}%");
    let _ = writeln!(out, "  TF2.3 model on TF2.7 measurements: MAPE={skew:6.2}%");

    // recalibrate: retrain (single anchor-target pair, fast) on a TF2.7
    // corpus and re-evaluate.
    let mut corpus27 = crate::data::Corpus::default();
    for e in &ctx.corpus.entries {
        let w = e.workload;
        let mut runs = std::collections::BTreeMap::new();
        for inst in [anchor, target] {
            if let Some(r) = sim::workload::run_workload_sdk(&w, inst, SdkVersion::Tf27) {
                runs.insert(
                    inst,
                    crate::data::RunData {
                        profile: r.profile.aggregated(),
                        latency_ms: r.latency_ms,
                    },
                );
            }
        }
        if !runs.is_empty() {
            corpus27.entries.push(crate::data::Entry { workload: w, runs });
        }
    }
    let (train27, test27) = corpus27.split_random(0.2, super::SPLIT_SEED);
    let opts = TrainOptions {
        anchors: vec![anchor],
        targets: vec![target],
        n_trees: if ctx.fast { 25 } else { 60 },
        dnn_epochs: if ctx.fast { 12 } else { 30 },
        ..Default::default()
    };
    let profet27 = Profet::train(&ctx.rt, &corpus27, &train27, &opts)?;
    let mut truth = Vec::new();
    let mut pred = Vec::new();
    for &i in &test27 {
        let e = &corpus27.entries[i];
        let (Some(a), Some(t)) = (e.runs.get(&anchor), e.runs.get(&target)) else {
            continue;
        };
        let (p, _) = profet27.predict_cross(&ctx.rt, anchor, target, &a.profile, a.latency_ms)?;
        truth.push(t.latency_ms);
        pred.push(p);
    }
    let recal = metrics::mape(&truth, &pred);
    let _ = writeln!(out, "  recalibrated on TF2.7:             MAPE={recal:6.2}%");
    out.push_str(&check(
        "SDK skew degrades accuracy (the Sec VII caveat)",
        skew > same * 1.15,
    ));
    out.push_str(&check(
        "recalibration on the new SDK recovers accuracy",
        recal < skew * 0.8,
    ));
    Ok(out)
}

/// Non-CNN (transformer) prediction with the CNN-trained system.
pub fn ext_transformer(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let mut out = String::from("== Extension (Sec VII): transformer workloads, CNN-trained model ==\n");
    let anchor = Instance::G4dn;

    let mut apes = Vec::new();
    for model in ModelId::EXTENDED {
        for seq in [64usize, 128, 256] {
            for batch in [16usize, 32] {
                let w = Workload::new(model, batch, seq);
                let Some(run_a) = sim::run_workload(&w, anchor) else {
                    continue;
                };
                for target in [Instance::P3, Instance::P2] {
                    let Some(run_t) = sim::run_workload(&w, target) else {
                        continue;
                    };
                    let (p, _) = profet.predict_cross(
                        &ctx.rt,
                        anchor,
                        target,
                        &run_a.profile.aggregated(),
                        run_a.latency_ms,
                    )?;
                    apes.push(100.0 * (p - run_t.latency_ms).abs() / run_t.latency_ms);
                }
            }
        }
    }
    let tf_mape = crate::util::mean(&apes);

    // reference: the CNN test-set MAPE of the same system
    let test_idx = ctx.test_idx.clone();
    let preds = super::figures::collect_member_preds(
        ctx,
        profet,
        &[anchor],
        &[Instance::P3, Instance::P2],
        &test_idx,
    )?;
    let cnn_mape = metrics::mape(&preds.truth, &preds.median);

    let _ = writeln!(out, "  CNN test workloads:        MAPE={cnn_mape:6.2}%");
    let _ = writeln!(
        out,
        "  transformer workloads:     MAPE={tf_mape:6.2}%  (n={})",
        apes.len()
    );
    out.push_str(&check(
        "CNN-trained PROFET degrades on non-CNN models (the Sec VII caveat)",
        tf_mape > cnn_mape * 1.5,
    ));
    out.push_str(&check(
        "but clustering keeps it better than chance (< 100% MAPE)",
        tf_mape < 100.0,
    ));
    Ok(out)
}
