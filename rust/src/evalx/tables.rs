//! Table reproductions (Tables I, II, III, IV, V, VI).

use super::figures::collect_member_preds;
use super::{check, Ctx};
use crate::baselines::{habitat, mlpredict::MlPredict, paleo};
use crate::dnn::{DnnRegressor, TrainConfig};
use crate::gpu::Instance;
use crate::ml::{metrics, FeatureMatrix, RandomForest};
use crate::models::ModelId;
use crate::predictor::Profet;
use crate::sim::{self, workload::BATCHES, workload::PIXELS, Workload};
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Table I: instance specifications.
pub fn table1() -> String {
    let mut out = String::from("== Table I: AWS GPU instance specifications ==\n");
    let _ = writeln!(
        out,
        "  {:8} {:6} {:>6} {:>10} {:>12} {:>6} {:>9}",
        "family", "GPU", "cores", "clock(MHz)", "TFLOPS(FP32)", "year", "price($)"
    );
    for i in Instance::CORE {
        let s = i.spec();
        let _ = writeln!(
            out,
            "  {:8} {:6} {:>6} {:>10} {:>12.3} {:>6} {:>9.3}",
            i.key(),
            s.gpu_model,
            s.cores,
            s.clock_mhz,
            s.tflops_fp32,
            s.released,
            s.price_hr
        );
    }
    out
}

/// One-hot helpers for the joint model's extra inputs.
fn one_hot<T: PartialEq>(val: T, domain: &[T]) -> Vec<f64> {
    domain.iter().map(|d| if *d == val { 1.0 } else { 0.0 }).collect()
}

/// Joint-modeling feature row: clustered anchor-profile features followed
/// by one-hot(target instance) + one-hot(target batch), padded to width.
fn joint_row(
    profet_features: &[f64],
    n_features: usize,
    target: Instance,
    batch: usize,
    width: usize,
) -> Vec<f64> {
    let mut row = Vec::with_capacity(width);
    row.extend_from_slice(&profet_features[..n_features]);
    row.extend(one_hot(target, &Instance::CORE));
    row.extend(one_hot(batch, &BATCHES));
    row.resize(width, 0.0);
    row
}

/// Table II: joint vs separate modeling.
///
/// Scenario set (both methods see the same tasks): predict the latency of
/// (model, b_t, pixels) on a target instance from the anchor (g4dn)
/// profile of the SAME model/pixels at the min batch size. Joint models
/// consume one-hot(target, b_t) inputs directly; Separate (PROFET)
/// composes cross-instance + batch-polynomial phases.
pub fn table2(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let mut out = String::from("== Table II: joint vs separate modeling ==\n");
    let anchor = Instance::G4dn;
    let targets = [Instance::G3s, Instance::P2, Instance::P3];
    let width = ctx.rt.meta.d_feat;

    // scenario tuples: (entry_min_idx, entry_max_idx, target, b, truth_idx)
    // built from (model, pixels) groups that have b=16 and b=256 runs.
    let mut groups: BTreeMap<(String, usize), BTreeMap<usize, usize>> = BTreeMap::new();
    for (i, e) in ctx.corpus.entries.iter().enumerate() {
        if e.runs.contains_key(&anchor) {
            groups
                .entry((e.workload.model.name().into(), e.workload.pixels))
                .or_default()
                .insert(e.workload.batch, i);
        }
    }
    struct Scenario {
        i_min: usize,
        i_max: usize,
        i_b: usize,
        target: Instance,
        b: usize,
    }
    let mut scenarios = Vec::new();
    let test_set: std::collections::BTreeSet<usize> = ctx.test_idx.iter().copied().collect();
    for batches in groups.values() {
        let (Some(&i16), Some(&i256)) = (batches.get(&16), batches.get(&256)) else {
            continue;
        };
        for (&b, &ib) in batches {
            if !test_set.contains(&ib) {
                continue; // evaluate on held-out workloads only
            }
            for t in targets {
                if ctx.corpus.entries[ib].runs.contains_key(&t) {
                    scenarios.push(Scenario {
                        i_min: i16,
                        i_max: i256,
                        i_b: ib,
                        target: t,
                        b,
                    });
                }
            }
        }
    }
    anyhow::ensure!(!scenarios.is_empty(), "no joint/separate scenarios");

    // ---- joint training set from the train split
    let profet = ctx.profet.as_ref().unwrap();
    let nfeat = profet.feature_space.n_features();
    let mut jx = Vec::new();
    let mut jy = Vec::new();
    for batches in groups.values() {
        let Some(&i16) = batches.get(&16) else { continue };
        if test_set.contains(&i16) {
            continue;
        }
        let e16 = &ctx.corpus.entries[i16];
        let Some(a16) = e16.runs.get(&anchor) else { continue };
        let base = profet.feature_space.vectorize(&a16.profile);
        for (&b, &ib) in batches {
            if test_set.contains(&ib) {
                continue;
            }
            for t in targets {
                if let Some(run) = ctx.corpus.entries[ib].runs.get(&t) {
                    jx.push(joint_row(&base, nfeat, t, b, width));
                    jy.push(run.latency_ms);
                }
            }
        }
    }
    let jx = FeatureMatrix::from_rows(&jx)?;
    let joint_rf = RandomForest::fit(&jx, &jy, if ctx.fast { 25 } else { 100 }, 0x101971)?;
    let joint_dnn = DnnRegressor::fit(
        &ctx.rt,
        &jx,
        &jy,
        TrainConfig {
            epochs: if ctx.fast { 10 } else { 30 },
            seed: 0x7AB1E2,
        },
    )?;

    // ---- evaluate all four columns on the scenarios
    let mut truth = Vec::new();
    let mut p_joint_rf = Vec::new();
    let mut joint_rows = Vec::new();
    let mut p_sep_rf = Vec::new();
    let mut p_sep_dnn = Vec::new();
    for s in &scenarios {
        let e_min = &ctx.corpus.entries[s.i_min];
        let e_max = &ctx.corpus.entries[s.i_max];
        let a_min = &e_min.runs[&anchor];
        let a_max = &e_max.runs[&anchor];
        let t_run = &ctx.corpus.entries[s.i_b].runs[&s.target];
        truth.push(t_run.latency_ms);

        let base = profet.feature_space.vectorize(&a_min.profile);
        let row = joint_row(&base, nfeat, s.target, s.b, width);
        p_joint_rf.push(joint_rf.predict_one(&row));
        joint_rows.push(row);

        // separate: phase-1 with member X, phase-2 polynomial
        let cm = profet.cross.get(&(anchor, s.target)).unwrap();
        let x_min = profet.feature_space.vectorize(&a_min.profile);
        let x_max = profet.feature_space.vectorize(&a_max.profile);
        let rf_min = cm.forest.predict_one(&x_min);
        let rf_max = cm.forest.predict_one(&x_max);
        p_sep_rf.push(profet.predict_batch_size(s.target, s.b, rf_min, rf_max)?);
        let dnn_min = cm.dnn.predict_one(&ctx.rt, &x_min)?;
        let dnn_max = cm.dnn.predict_one(&ctx.rt, &x_max)?;
        p_sep_dnn.push(profet.predict_batch_size(s.target, s.b, dnn_min, dnn_max)?);
    }
    let joint_rows = FeatureMatrix::from_rows(&joint_rows)?;
    let p_joint_dnn = joint_dnn.predict(&ctx.rt, &joint_rows)?;

    let rows = [
        ("Joint RandomForest", &p_joint_rf),
        ("Joint DNN", &p_joint_dnn),
        ("Separate RandomForest", &p_sep_rf),
        ("Separate DNN (PROFET)", &p_sep_dnn),
    ];
    let mut mapes = BTreeMap::new();
    for (name, p) in rows {
        let s = metrics::scores(&truth, p);
        mapes.insert(name, s.mape);
        let _ = writeln!(
            out,
            "  {name:22} MAPE={:9.4}  R2={:8.4}  RMSE={:9.3}   (n={})",
            s.mape,
            s.r2,
            s.rmse,
            truth.len()
        );
    }
    out.push_str(&check(
        "separate modeling beats joint for RandomForest",
        mapes["Separate RandomForest"] < mapes["Joint RandomForest"],
    ));
    out.push_str(&check(
        "separate modeling beats joint for DNN",
        mapes["Separate DNN (PROFET)"] < mapes["Joint DNN"],
    ));
    Ok(out)
}

/// Table III: Paleo vs PROFET on the common models (AlexNet, VGG16).
///
/// Following the paper's methodology ("among experiment results conducted
/// by PROFET, we compare CNN models which are common to Paleo"), the
/// comparison runs over ALL corpus workloads of the two common models —
/// not only the held-out split, which contains too few AlexNet/VGG16
/// points for a stable RMSE.
pub fn table3(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let mut out = String::from("== Table III: Paleo vs PROFET (AlexNet, VGG16) ==\n");
    let mut truth = Vec::new();
    let mut p_paleo = Vec::new();
    let mut p_profet = Vec::new();
    for e in ctx.corpus.entries.iter() {
        if !matches!(e.workload.model, ModelId::AlexNet | ModelId::Vgg16) {
            continue;
        }
        let Ok(graph) = e.workload.graph() else { continue };
        for t in Instance::CORE {
            let Some(run) = e.runs.get(&t) else { continue };
            // every available anchor != target contributes a PROFET
            // prediction; Paleo (white-box) needs no anchor.
            for a in Instance::CORE {
                if a == t {
                    continue;
                }
                let Some(ar) = e.runs.get(&a) else { continue };
                let (pp, _) = profet.predict_cross(&ctx.rt, a, t, &ar.profile, ar.latency_ms)?;
                truth.push(run.latency_ms);
                p_profet.push(pp);
                p_paleo.push(paleo::predict(&graph, t.spec()));
            }
        }
    }
    let sp = metrics::scores(&truth, &p_paleo);
    let sf = metrics::scores(&truth, &p_profet);
    let _ = writeln!(out, "  {:8} {:>10} {:>10}", "", "PALEO", "PROFET");
    let _ = writeln!(out, "  {:8} {:>10.4} {:>10.4}", "MAPE", sp.mape, sf.mape);
    let _ = writeln!(out, "  {:8} {:>10.5} {:>10.5}", "R2", sp.r2, sf.r2);
    let _ = writeln!(out, "  {:8} {:>10.4} {:>10.4}   (n={})", "RMSE", sp.rmse, sf.rmse, truth.len());
    out.push_str(&check("PROFET MAPE lower than Paleo", sf.mape < sp.mape));
    out.push_str(&check("PROFET RMSE lower than Paleo", sf.rmse < sp.rmse));
    Ok(out)
}

/// Table IV: MLPredict vs PROFET, VGG16 across batch sizes.
pub fn table4(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let mut out = String::from("== Table IV: MLPredict vs PROFET (VGG16, per batch size) ==\n");
    // MLPredict models per target, trained on the small-batch regime
    let train_workloads: Vec<Workload> = ctx
        .train_idx
        .iter()
        .map(|&i| ctx.corpus.entries[i].workload)
        .collect();
    let mut ml_models = BTreeMap::new();
    for t in Instance::CORE {
        ml_models.insert(t, MlPredict::fit(t, &train_workloads)?);
    }

    let _ = writeln!(
        out,
        "  {:>5} | {:>12} {:>8} | {:>12} {:>8}",
        "batch", "MLPredict", "PROFET", "MLPredict", "PROFET"
    );
    let _ = writeln!(out, "  {:>5} | {:^21} | {:^21}", "", "MAPE (%)", "RMSE");
    let mut ml_mapes = Vec::new();
    let mut pf_mapes = Vec::new();
    for b in [16usize, 32, 64, 128] {
        let mut truth = Vec::new();
        let mut p_ml = Vec::new();
        let mut p_pf = Vec::new();
        for p in PIXELS {
            let w = Workload::new(ModelId::Vgg16, b, p);
            let Ok(graph) = w.graph() else { continue };
            for t in Instance::CORE {
                let Some(run) = sim::run_workload(&w, t) else { continue };
                // MLPredict
                p_ml.push(ml_models[&t].predict(&graph));
                // PROFET from the first fitting anchor
                let Some((a, ar)) = Instance::CORE.iter().filter(|&&a| a != t).find_map(|&a| {
                    sim::run_workload(&w, a).map(|r| (a, r))
                }) else {
                    continue;
                };
                let (pp, _) = profet.predict_cross(
                    &ctx.rt,
                    a,
                    t,
                    &ar.profile.aggregated(),
                    ar.latency_ms,
                )?;
                p_pf.push(pp);
                truth.push(run.latency_ms);
            }
        }
        let sm = metrics::scores(&truth, &p_ml);
        let sf = metrics::scores(&truth, &p_pf);
        ml_mapes.push(sm.mape);
        pf_mapes.push(sf.mape);
        let _ = writeln!(
            out,
            "  {b:>5} | {:>12.2} {:>8.2} | {:>12.2} {:>8.2}",
            sm.mape, sf.mape, sm.rmse, sf.rmse
        );
    }
    out.push_str(&check(
        "PROFET beats MLPredict at every batch size",
        ml_mapes.iter().zip(&pf_mapes).all(|(m, p)| p < m),
    ));
    out.push_str(&check(
        "MLPredict error grows sharply with batch size",
        ml_mapes[3] > 2.0 * ml_mapes[0],
    ));
    Ok(out)
}

/// Table V: Habitat vs PROFET, T4 <-> V100.
pub fn table5(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let mut out = String::from("== Table V: Habitat vs PROFET (MAPE, T4 <-> V100) ==\n");
    let models = [ModelId::ResNet50, ModelId::InceptionV3, ModelId::Vgg16];
    let mut results = Vec::new();
    for (a, t) in [(Instance::G4dn, Instance::P3), (Instance::P3, Instance::G4dn)] {
        let mut truth = Vec::new();
        let mut p_hab = Vec::new();
        let mut p_pf = Vec::new();
        for m in models {
            for b in [16usize, 32, 64] {
                for p in PIXELS {
                    let w = Workload::new(m, b, p);
                    let Ok(graph) = w.graph() else { continue };
                    let (Some(run_t), Some(run_a)) =
                        (sim::run_workload(&w, t), sim::run_workload(&w, a))
                    else {
                        continue;
                    };
                    truth.push(run_t.latency_ms);
                    p_hab.push(habitat::predict(&graph, a, t));
                    let (pp, _) = profet.predict_cross(
                        &ctx.rt,
                        a,
                        t,
                        &run_a.profile.aggregated(),
                        run_a.latency_ms,
                    )?;
                    p_pf.push(pp);
                }
            }
        }
        let mh = metrics::mape(&truth, &p_hab);
        let mp = metrics::mape(&truth, &p_pf);
        results.push((mh, mp));
        let _ = writeln!(
            out,
            "  {} -> {}   Habitat={mh:6.2}  PROFET={mp:6.2}   (n={})",
            a.spec().gpu_model,
            t.spec().gpu_model,
            truth.len()
        );
    }
    out.push_str(&check(
        "PROFET average MAPE below Habitat's",
        results.iter().map(|r| r.1).sum::<f64>() < results.iter().map(|r| r.0).sum::<f64>(),
    ));
    Ok(out)
}

/// Table VI: predicting latency on new GPUs (A10/G5, P100/AC1).
pub fn table6(ctx: &mut Ctx) -> Result<String> {
    let mut out = String::from("== Table VI: new-GPU targets from existing anchors (MAPE) ==\n");
    let mut opts = ctx.train_opts();
    opts.anchors = Instance::CORE.to_vec();
    opts.targets = Instance::NEW.to_vec();
    let train_idx = ctx.train_idx.clone();
    let profet_new = Profet::train(&ctx.rt, &ctx.corpus, &train_idx, &opts)?;
    let test_idx = ctx.test_idx.clone();

    let _ = writeln!(
        out,
        "  {:16} {:>9} {:>9} {:>9} {:>9}",
        "target \\ anchor", "M60(g3s)", "T4(g4dn)", "K80(p2)", "V100(p3)"
    );
    let mut new_gpu_mapes = Vec::new();
    for t in Instance::NEW {
        let mut row = format!(
            "  {:16}",
            format!("{} ({})", t.spec().gpu_model, t.key())
        );
        for a in Instance::CORE {
            let preds = collect_member_preds(ctx, &profet_new, &[a], &[t], &test_idx)?;
            let m = metrics::mape(&preds.truth, &preds.median);
            new_gpu_mapes.push(m);
            let _ = write!(row, " {m:>9.2}");
        }
        out.push_str(&row);
        out.push('\n');
    }
    let avg = crate::util::mean(&new_gpu_mapes);
    let _ = writeln!(out, "  average new-GPU MAPE: {avg:.2}%");
    out.push_str(&check(
        "average new-GPU MAPE stays in the seen-GPU band (< 20%)",
        avg < 20.0,
    ));
    out.push_str(&check(
        "no anchor-target pair collapses (every MAPE < 40%)",
        new_gpu_mapes.iter().all(|&m| m < 40.0),
    ));
    out.push_str(&check(
        "Ampere-generation A10 predictable from pre-Ampere anchors (avg < 20%)",
        crate::util::mean(&new_gpu_mapes[..4]) < 20.0,
    ));
    Ok(out)
}
