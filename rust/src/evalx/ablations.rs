//! Design-choice ablations (DESIGN.md §5 extras).
//!
//! The paper states three empirical choices without showing the sweeps:
//! dendrogram cut height = 6 (Sec III-B3 "In our thorough empirical
//! analysis, setting the maximum height as six results in the best
//! prediction accuracy"), average linkage (Sec III-B2 "based on empirical
//! analysis"), and the *median* ensemble (Sec III-C1, vs. plain mean
//! bagging). These experiments regenerate those sweeps on our corpus.

use super::figures::collect_member_preds;
use super::{check, Ctx};
use crate::features::{Dendrogram, FeatureSpace};
use crate::gpu::Instance;
use crate::ml::metrics;
use crate::predictor::Profet;
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Train a single-anchor PROFET against a given feature space override by
/// re-fitting with clustering on but a custom cut — we emulate by fitting
/// FeatureSpace directly and measuring RF-only accuracy (the member most
/// sensitive to the feature definition; DNN retraining per sweep point
/// would dominate runtime without changing the ordering).
fn rf_mape_for_space(ctx: &Ctx, fs: &FeatureSpace) -> Result<f64> {
    use crate::ml::{FeatureMatrix, RandomForest};
    let anchor = Instance::G4dn;
    let mut mapes = Vec::new();
    for target in [Instance::G3s, Instance::P2, Instance::P3] {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for &i in &ctx.train_idx {
            let e = &ctx.corpus.entries[i];
            let (Some(a), Some(t)) = (e.runs.get(&anchor), e.runs.get(&target)) else {
                continue;
            };
            x.push(fs.vectorize(&a.profile));
            y.push(t.latency_ms);
        }
        let n_trees = if ctx.fast { 25 } else { 60 };
        let rf = RandomForest::fit(&FeatureMatrix::from_rows(&x)?, &y, n_trees, 77)?;
        let mut truth = Vec::new();
        let mut rows = Vec::new();
        for &i in &ctx.test_idx {
            let e = &ctx.corpus.entries[i];
            let (Some(a), Some(t)) = (e.runs.get(&anchor), e.runs.get(&target)) else {
                continue;
            };
            truth.push(t.latency_ms);
            rows.push(fs.vectorize(&a.profile));
        }
        let pred = rf.predict_batch(&FeatureMatrix::from_rows(&rows)?);
        mapes.push(metrics::mape(&truth, &pred));
    }
    Ok(crate::util::mean(&mapes))
}

/// Sweep the dendrogram cut height (paper fixed it at 6).
pub fn abl_cut_height(ctx: &mut Ctx) -> Result<String> {
    let mut out = String::from("== Ablation: dendrogram cut height (paper: 6) ==\n");
    let vocab_owned = ctx.corpus.vocabulary();
    let vocab: Vec<&str> = vocab_owned.iter().map(|s| s.as_str()).collect();
    let dendro = Dendrogram::build(&vocab);
    let mut results = BTreeMap::new();
    for cut in [0usize, 2, 4, 6, 8, 12, 20] {
        let clusters = dendro.cut(cut as f64);
        let fs = FeatureSpace::from_clusters(clusters, true, ctx.rt.meta.d_feat)?;
        let mape = rf_mape_for_space(ctx, &fs)?;
        let _ = writeln!(
            out,
            "  cut={cut:2}  features={:2}  RF MAPE={mape:6.2}%",
            fs.n_features()
        );
        results.insert(cut, mape);
    }
    let best = results
        .iter()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(c, _)| *c)
        .unwrap();
    let _ = writeln!(out, "  best cut on this corpus: {best}");
    out.push_str(&check(
        "moderate cut (2..=8) no worse than extremes (0 or 20)",
        {
            let mid = results[&4].min(results[&6]).min(results[&8]).min(results[&2]);
            mid <= results[&0] + 0.5 && mid <= results[&20] + 0.5
        },
    ));
    Ok(out)
}

/// Compare linkage heuristics (paper: average, "based on empirical
/// analysis"; alternatives: single, complete).
pub fn abl_linkage(ctx: &mut Ctx) -> Result<String> {
    let mut out = String::from("== Ablation: clustering linkage (paper: average) ==\n");
    let vocab_owned = ctx.corpus.vocabulary();
    let vocab: Vec<&str> = vocab_owned.iter().map(|s| s.as_str()).collect();
    let mut results = BTreeMap::new();
    for linkage in ["single", "average", "complete"] {
        let clusters = crate::features::linkage_clusters(&vocab, 6.0, linkage);
        let fs = FeatureSpace::from_clusters(clusters, true, ctx.rt.meta.d_feat)?;
        let mape = rf_mape_for_space(ctx, &fs)?;
        let _ = writeln!(
            out,
            "  {linkage:8}  features={:2}  RF MAPE={mape:6.2}%",
            fs.n_features()
        );
        results.insert(linkage, mape);
    }
    // Which linkage wins is corpus-dependent (the paper picked average on
    // its 65-op vocabulary; on ours, coarser single-linkage families can
    // edge it out). The robust claim is that the choice is not critical:
    out.push_str(&check(
        "linkage choice is not critical (all within a 6% MAPE band)",
        {
            let vals: Vec<f64> = results.values().copied().collect();
            let mx = vals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let mn = vals.iter().copied().fold(f64::INFINITY, f64::min);
            mx - mn < 6.0
        },
    ));
    Ok(out)
}

/// Median vs mean ensembling, and each member alone (extends Fig 10).
pub fn abl_ensemble(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet: &Profet = ctx.profet.as_ref().unwrap();
    let test_idx = ctx.test_idx.clone();
    let preds = collect_member_preds(ctx, profet, &Instance::CORE, &Instance::CORE, &test_idx)?;
    let mean_preds: Vec<f64> = (0..preds.truth.len())
        .map(|k| (preds.linear[k] + preds.forest[k] + preds.dnn[k]) / 3.0)
        .collect();
    let mut out = String::from("== Ablation: median vs mean ensembling ==\n");
    let median_mape = metrics::mape(&preds.truth, &preds.median);
    let mean_mape = metrics::mape(&preds.truth, &mean_preds);
    let _ = writeln!(out, "  median ensemble MAPE={median_mape:7.3}%");
    let _ = writeln!(out, "  mean   ensemble MAPE={mean_mape:7.3}%");
    // pairwise (drop-one) medians: median of 2 = mean of 2
    for (name, a, b) in [
        ("linear+forest", &preds.linear, &preds.forest),
        ("linear+dnn", &preds.linear, &preds.dnn),
        ("forest+dnn", &preds.forest, &preds.dnn),
    ] {
        let two: Vec<f64> = a.iter().zip(b).map(|(x, y)| 0.5 * (x + y)).collect();
        let _ = writeln!(
            out,
            "  pair {name:15} MAPE={:7.3}%",
            metrics::mape(&preds.truth, &two)
        );
    }
    out.push_str(&check(
        "median ensembling beats mean ensembling (robustness to outlier members)",
        median_mape < mean_mape,
    ));
    Ok(out)
}
