//! Figure reproductions (Fig 2, 9, 10, 11, 12, 13).

use super::{check, Ctx};
use crate::data::Corpus;
use crate::gpu::Instance;
use crate::ml::{metrics, FeatureMatrix};
use crate::models::ModelId;
use crate::predictor::{BatchPixelModel, Member, Profet};
use crate::sim::{self, Workload};
use crate::util::quantile;
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt::Write as _;

fn run_latency(m: ModelId, b: usize, p: usize, g: Instance) -> f64 {
    sim::run_workload(&Workload::new(m, b, p), g)
        .map(|r| r.latency_ms)
        .unwrap_or(f64::NAN)
}

/// Fig 2a: LeNet5 / AlexNet latency + relative cost across instances.
pub fn fig2a() -> String {
    let mut out = String::from("== Fig 2a: latency & cost across instances (32px, b=16) ==\n");
    let mut best: BTreeMap<ModelId, (Instance, f64)> = BTreeMap::new();
    for model in [ModelId::LeNet5, ModelId::AlexNet] {
        let lats: Vec<(Instance, f64)> = Instance::CORE
            .iter()
            .map(|&g| (g, run_latency(model, 16, 32, g)))
            .collect();
        let lmin = lats.iter().map(|x| x.1).fold(f64::INFINITY, f64::min);
        let costs: Vec<f64> = lats.iter().map(|(g, l)| l * g.spec().price_hr).collect();
        let cmin = costs.iter().copied().fold(f64::INFINITY, f64::min);
        let _ = writeln!(out, "  {model}:");
        for ((g, l), c) in lats.iter().zip(&costs) {
            let _ = writeln!(
                out,
                "    {:5} latency={:8.2} ms  norm={:5.2}  rel-cost={:5.2}",
                g.key(),
                l,
                l / lmin,
                c / cmin
            );
        }
        let fastest = lats
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        best.insert(model, *fastest);
    }
    out.push_str(&check("g4dn fastest for LeNet5", best[&ModelId::LeNet5].0 == Instance::G4dn));
    out.push_str(&check("p3 fastest for AlexNet", best[&ModelId::AlexNet].0 == Instance::P3));
    let alex: Vec<f64> = Instance::CORE
        .iter()
        .map(|&g| run_latency(ModelId::AlexNet, 16, 32, g))
        .collect();
    let spread = alex.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b))
        / alex.iter().fold(f64::INFINITY, |a, &b| a.min(b));
    out.push_str(&check(
        "AlexNet best/worst spread larger than LeNet5's",
        {
            let le: Vec<f64> = Instance::CORE
                .iter()
                .map(|&g| run_latency(ModelId::LeNet5, 16, 32, g))
                .collect();
            let le_spread = le.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b))
                / le.iter().fold(f64::INFINITY, |a, &b| a.min(b));
            spread > le_spread
        },
    ));
    out
}

/// Fig 2b: ResNet50 at 32² vs 128² pixels.
pub fn fig2b() -> String {
    let mut out = String::from("== Fig 2b: ResNet50 latency & cost, 32px vs 128px (b=16) ==\n");
    let mut winners = Vec::new();
    for px in [32usize, 128] {
        let _ = writeln!(out, "  {px}x{px}:");
        let lats: Vec<(Instance, f64)> = Instance::CORE
            .iter()
            .map(|&g| (g, run_latency(ModelId::ResNet50, 16, px, g)))
            .collect();
        for (g, l) in &lats {
            let _ = writeln!(
                out,
                "    {:5} latency={:8.2} ms  cost-unit={:8.2}",
                g.key(),
                l,
                l * g.spec().price_hr
            );
        }
        winners.push(
            lats.iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0,
        );
    }
    out.push_str(&check(
        "p3 shortest latency at both pixel sizes",
        winners.iter().all(|&w| w == Instance::P3),
    ));
    let gap32 = run_latency(ModelId::ResNet50, 16, 32, Instance::G4dn)
        / run_latency(ModelId::ResNet50, 16, 32, Instance::P3);
    let gap128 = run_latency(ModelId::ResNet50, 16, 128, Instance::G4dn)
        / run_latency(ModelId::ResNet50, 16, 128, Instance::P3);
    out.push_str(&check(
        "p3/g4dn gap grows with image size",
        gap128 > gap32,
    ));
    let cost_g4 = run_latency(ModelId::ResNet50, 16, 128, Instance::G4dn)
        * Instance::G4dn.spec().price_hr;
    let cost_p3 =
        run_latency(ModelId::ResNet50, 16, 128, Instance::P3) * Instance::P3.spec().price_hr;
    out.push_str(&check("g4dn more cost-efficient than p3", cost_g4 < cost_p3));
    out
}

/// Fig 2c: batch-latency ratio quantiles per instance.
pub fn fig2c() -> String {
    let mut out =
        String::from("== Fig 2c: latency ratio vs batch size (ratio to b=16; quantiles) ==\n");
    let mut medians_at_256: BTreeMap<Instance, f64> = BTreeMap::new();
    for g in Instance::CORE {
        let _ = writeln!(out, "  {}:", g.key());
        for b in [32usize, 64, 128, 256] {
            let mut ratios = Vec::new();
            for m in ModelId::ALL {
                for p in crate::sim::workload::PIXELS {
                    let w16 = sim::run_workload(&Workload::new(m, 16, p), g);
                    let wb = sim::run_workload(&Workload::new(m, b, p), g);
                    if let (Some(a), Some(c)) = (w16, wb) {
                        ratios.push(c.latency_ms / a.latency_ms);
                    }
                }
            }
            let _ = writeln!(
                out,
                "    b={b:3}  min={:5.2} q25={:5.2} med={:5.2} q75={:5.2} max={:6.2}  (n={})",
                quantile(&ratios, 0.0),
                quantile(&ratios, 0.25),
                quantile(&ratios, 0.5),
                quantile(&ratios, 0.75),
                quantile(&ratios, 1.0),
                ratios.len()
            );
            if b == 256 {
                medians_at_256.insert(g, quantile(&ratios, 0.5));
            }
        }
    }
    out.push_str(&check(
        "relationship non-linear: median ratio at b=256 well below 16x everywhere",
        medians_at_256.values().all(|&r| r < 14.0),
    ));
    out.push_str(&check(
        "p3 shows the lowest latency increase with batch size",
        medians_at_256[&Instance::P3]
            <= *medians_at_256
                .iter()
                .filter(|(g, _)| **g != Instance::P3)
                .map(|(_, v)| v)
                .min_by(|a, b| a.partial_cmp(b).unwrap())
                .unwrap(),
    ));
    out
}

/// Per-(anchor,target) test-set predictions for every ensemble member.
pub(crate) struct MemberPreds {
    pub truth: Vec<f64>,
    pub linear: Vec<f64>,
    pub forest: Vec<f64>,
    pub dnn: Vec<f64>,
    pub median: Vec<f64>,
    pub picks: BTreeMap<&'static str, usize>,
}

pub(crate) fn collect_member_preds(
    ctx: &Ctx,
    profet: &Profet,
    anchors: &[Instance],
    targets: &[Instance],
    test_idx: &[usize],
) -> Result<MemberPreds> {
    let mut out = MemberPreds {
        truth: vec![],
        linear: vec![],
        forest: vec![],
        dnn: vec![],
        median: vec![],
        picks: BTreeMap::new(),
    };
    for &a in anchors {
        for &t in targets {
            if a == t {
                continue;
            }
            let Some(model) = profet.cross.get(&(a, t)) else {
                continue;
            };
            // batch the DNN forward for the whole test slice
            let mut feats = Vec::new();
            let mut anchor_lat = Vec::new();
            let mut truth = Vec::new();
            for &i in test_idx {
                let e = &ctx.corpus.entries[i];
                let (Some(ar), Some(tr)) = (e.runs.get(&a), e.runs.get(&t)) else {
                    continue;
                };
                feats.push(profet.feature_space.vectorize(&ar.profile));
                anchor_lat.push(ar.latency_ms);
                truth.push(tr.latency_ms);
            }
            if feats.is_empty() {
                continue;
            }
            // batch the DNN artifact and the cache-hot forest pass together
            let fm = FeatureMatrix::from_rows(&feats)?;
            let dnn = model.dnn.predict(&ctx.rt, &fm)?;
            let forest = model.forest.predict_batch(&fm);
            for k in 0..fm.n_rows() {
                let l = model.linear.predict_one(&[anchor_lat[k]]);
                let f = forest[k];
                let d = dnn[k];
                let mut v = [(l, Member::Linear), (f, Member::Forest), (d, Member::Dnn)];
                v.sort_by(|p, q| p.0.partial_cmp(&q.0).unwrap());
                out.truth.push(truth[k]);
                out.linear.push(l);
                out.forest.push(f);
                out.dnn.push(d);
                out.median.push(v[1].0);
                *out.picks.entry(v[1].1.name()).or_insert(0) += 1;
            }
        }
    }
    Ok(out)
}

/// Fig 9: true vs predicted scatter per anchor instance.
pub fn fig9(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let mut out = String::from("== Fig 9: true vs predicted latency per anchor (test split) ==\n");
    let test_idx = ctx.test_idx.clone();
    for a in Instance::CORE {
        let preds = collect_member_preds(ctx, profet, &[a], &Instance::CORE, &test_idx)?;
        let s = metrics::scores(&preds.truth, &preds.median);
        let _ = writeln!(
            out,
            "  anchor {:5}  n={:4}  MAPE={:7.3}%  RMSE={:8.2}  R2={:.4}",
            a.key(),
            preds.truth.len(),
            s.mape,
            s.rmse,
            s.r2
        );
        // a few scatter samples (true, pred)
        let step = (preds.truth.len() / 5).max(1);
        for k in (0..preds.truth.len()).step_by(step).take(5) {
            let _ = writeln!(
                out,
                "      sample true={:9.2} ms  pred={:9.2} ms",
                preds.truth[k], preds.median[k]
            );
        }
        out.push_str(&check(
            &format!("anchor {} R2 > 0.9 (paper: points hug y=x)", a.key()),
            s.r2 > 0.9,
        ));
    }
    Ok(out)
}

/// Fig 10: median ensemble vs the single models.
pub fn fig10(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let test_idx = ctx.test_idx.clone();
    let preds = collect_member_preds(
        ctx,
        profet,
        &Instance::CORE,
        &Instance::CORE,
        &test_idx,
    )?;
    let mut out = String::from("== Fig 10: prediction accuracy by model (all anchor-target pairs) ==\n");
    let rows = [
        ("Linear", &preds.linear),
        ("RandomForest", &preds.forest),
        ("DNN", &preds.dnn),
        ("PROFET", &preds.median),
    ];
    let mut mapes = BTreeMap::new();
    for (name, p) in rows {
        let s = metrics::scores(&preds.truth, p);
        mapes.insert(name, s.mape);
        let _ = writeln!(
            out,
            "  {name:13} MAPE={:8.4}%  RMSE={:9.3}  R2={:7.4}",
            s.mape, s.rmse, s.r2
        );
    }
    let total: usize = preds.picks.values().sum();
    for (name, n) in &preds.picks {
        let _ = writeln!(
            out,
            "  median pick rate: {name:13} {:5.1}%",
            100.0 * *n as f64 / total as f64
        );
    }
    let best_single = mapes["Linear"].min(mapes["RandomForest"]).min(mapes["DNN"]);
    out.push_str(&check(
        "PROFET (median) beats or matches every single model on MAPE",
        mapes["PROFET"] <= best_single * 1.02,
    ));
    out.push_str(&check(
        "every member is picked a non-trivial fraction of the time",
        preds.picks.len() == 3 && preds.picks.values().all(|&n| n as f64 / total as f64 > 0.05),
    ));
    Ok(out)
}

/// Group lookup: (instance, model, pixels) -> batch -> corpus entry index.
fn batch_groups(
    corpus: &Corpus,
    instance: Instance,
) -> BTreeMap<(String, usize), BTreeMap<usize, usize>> {
    let mut groups: BTreeMap<(String, usize), BTreeMap<usize, usize>> = BTreeMap::new();
    for (i, e) in corpus.entries.iter().enumerate() {
        if e.runs.contains_key(&instance) {
            groups
                .entry((e.workload.model.name().into(), e.workload.pixels))
                .or_default()
                .insert(e.workload.batch, i);
        }
    }
    groups
}

/// Fig 11: batch-size predictor accuracy with True vs Predict min/max.
pub fn fig11(ctx: &mut Ctx) -> Result<String> {
    ctx.profet()?;
    let profet = ctx.profet.as_ref().unwrap();
    let mut out = String::from("== Fig 11: batch-size prediction MAPE (True vs Predict min/max) ==\n");
    let mut true_mape: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let mut pred_mape: BTreeMap<usize, Vec<f64>> = BTreeMap::new();

    for target in Instance::CORE {
        let groups = batch_groups(&ctx.corpus, target);
        for ((_, _), batches) in groups.iter() {
            let (Some(&i16), Some(&i256)) = (batches.get(&16), batches.get(&256)) else {
                continue;
            };
            let t16 = ctx.corpus.entries[i16].runs[&target].latency_ms;
            let t256 = ctx.corpus.entries[i256].runs[&target].latency_ms;
            for b in [32usize, 64, 128] {
                let Some(&ib) = batches.get(&b) else { continue };
                let truth = ctx.corpus.entries[ib].runs[&target].latency_ms;
                // True mode
                let p = profet.predict_batch_size(target, b, t16, t256)?;
                true_mape
                    .entry(b)
                    .or_default()
                    .push(100.0 * (p - truth).abs() / truth);
                // Predict mode: min/max latencies via cross-instance model
                // from one anchor (rotate anchors for coverage)
                for anchor in Instance::CORE {
                    if anchor == target {
                        continue;
                    }
                    let (Some(a16), Some(a256)) = (
                        ctx.corpus.entries[i16].runs.get(&anchor),
                        ctx.corpus.entries[i256].runs.get(&anchor),
                    ) else {
                        continue;
                    };
                    let (pmin, _) = profet.predict_cross(
                        &ctx.rt,
                        anchor,
                        target,
                        &a16.profile,
                        a16.latency_ms,
                    )?;
                    let (pmax, _) = profet.predict_cross(
                        &ctx.rt,
                        anchor,
                        target,
                        &a256.profile,
                        a256.latency_ms,
                    )?;
                    let p = profet.predict_batch_size(target, b, pmin, pmax)?;
                    pred_mape
                        .entry(b)
                        .or_default()
                        .push(100.0 * (p - truth).abs() / truth);
                    break; // one anchor per (group, target): keeps runtime sane
                }
            }
        }
    }

    let mut t_all = Vec::new();
    let mut p_all = Vec::new();
    for b in [32usize, 64, 128] {
        let t = crate::util::mean(true_mape.get(&b).unwrap_or(&vec![]));
        let p = crate::util::mean(pred_mape.get(&b).unwrap_or(&vec![]));
        let _ = writeln!(out, "  b={b:3}  True-minmax MAPE={t:6.2}%   Predict-minmax MAPE={p:6.2}%");
        t_all.push(t);
        p_all.push(p);
    }
    let t_avg = crate::util::mean(&t_all);
    let p_avg = crate::util::mean(&p_all);
    let _ = writeln!(out, "  avg   True={t_avg:6.2}%  Predict={p_avg:6.2}%");
    out.push_str(&check("True-minmax more accurate than Predict-minmax", t_avg < p_avg));
    out.push_str(&check("True-minmax MAPE in single digits", t_avg < 10.0));
    Ok(out)
}

/// Fig 12: polynomial order ablation for the batch/pixel model.
pub fn fig12(ctx: &mut Ctx) -> Result<String> {
    let mut out = String::from("== Fig 12: order-1 vs order-2 batch polynomial per instance ==\n");
    let mut order_mape: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let train_idx = ctx.train_idx.clone();
    for order in [1usize, 2] {
        let _ = writeln!(out, "  order-{order}:");
        for g in Instance::CORE {
            let m = BatchPixelModel::fit(&ctx.corpus, &train_idx, g, order)?;
            // evaluate on every group's interior batches with true min/max
            let groups = batch_groups(&ctx.corpus, g);
            let mut truth = Vec::new();
            let mut pred = Vec::new();
            for (_, batches) in groups {
                let (Some(&i16), Some(&i256)) = (batches.get(&16), batches.get(&256)) else {
                    continue;
                };
                let t16 = ctx.corpus.entries[i16].runs[&g].latency_ms;
                let t256 = ctx.corpus.entries[i256].runs[&g].latency_ms;
                for b in [32usize, 64, 128] {
                    if let Some(&ib) = batches.get(&b) {
                        truth.push(ctx.corpus.entries[ib].runs[&g].latency_ms);
                        pred.push(m.predict_batch(b, t16, t256));
                    }
                }
            }
            let s = metrics::scores(&truth, &pred);
            order_mape.entry(order).or_default().push(s.mape);
            let _ = writeln!(
                out,
                "    {:5} MAPE={:6.2}%  RMSE={:8.2}  R2={:.4}",
                g.key(),
                s.mape,
                s.rmse,
                s.r2
            );
        }
    }
    let m1 = crate::util::mean(&order_mape[&1]);
    let m2 = crate::util::mean(&order_mape[&2]);
    let _ = writeln!(out, "  avg MAPE: order-1 {m1:.2}%  order-2 {m2:.2}%");
    out.push_str(&check("order-2 outperforms order-1", m2 < m1));
    Ok(out)
}

/// Fig 13: feature-clustering ablation, leave-one-model-out.
pub fn fig13(ctx: &mut Ctx) -> Result<String> {
    let mut out = String::from(
        "== Fig 13: MAPE with clustering off/on (leave-one-model-out, anchor g4dn) ==\n",
    );
    let unique_models = [ModelId::MobileNetV2, ModelId::InceptionV3, ModelId::InceptionResNetV2];
    let common_models = [ModelId::ResNet34, ModelId::ResNet50, ModelId::Vgg16, ModelId::Vgg19];
    let mut improvements: BTreeMap<ModelId, f64> = BTreeMap::new();

    for (label, group) in [("(a) unique-op models", &unique_models[..]), ("(b) common-op models", &common_models[..])] {
        let _ = writeln!(out, "  {label}:");
        for &model in group {
            let (train_idx, test_idx) = ctx.corpus.split_by_model(model);
            let mut mapes = BTreeMap::new();
            for clustering in [false, true] {
                let mut opts = ctx.train_opts();
                opts.anchors = vec![Instance::G4dn];
                opts.targets = vec![Instance::G3s, Instance::P2, Instance::P3];
                opts.clustering = clustering;
                if !ctx.fast {
                    opts.dnn_epochs = 40; // 2x(models) x leave-one-out: trim
                }
                let profet = Profet::train(&ctx.rt, &ctx.corpus, &train_idx, &opts)?;
                let preds = collect_member_preds(
                    ctx,
                    &profet,
                    &[Instance::G4dn],
                    &[Instance::G3s, Instance::P2, Instance::P3],
                    &test_idx,
                )?;
                mapes.insert(clustering, metrics::mape(&preds.truth, &preds.median));
            }
            let off = mapes[&false];
            let on = mapes[&true];
            let improvement = 100.0 * (off - on) / off;
            improvements.insert(model, improvement);
            let _ = writeln!(
                out,
                "    {:18} clustering-off MAPE={off:7.2}%  on={on:7.2}%  improvement={improvement:+6.1}%",
                model.name()
            );
        }
    }
    let uniq_avg = crate::util::mean(
        &unique_models.iter().map(|m| improvements[m]).collect::<Vec<_>>(),
    );
    let common_avg = crate::util::mean(
        &common_models.iter().map(|m| improvements[m]).collect::<Vec<_>>(),
    );
    let _ = writeln!(
        out,
        "  avg improvement: unique-op models {uniq_avg:+.1}%, common-op models {common_avg:+.1}%"
    );
    out.push_str(&check(
        "clustering improves unique-op models",
        uniq_avg > 0.0,
    ));
    // Paper floor claim (Sec V-C): "MAPE improves the most with
    // InceptionV3 which is 29.9% ... at least 8.3%" — on our corpus the
    // star unique-op model is MobileNetV2 (its Relu6/DepthwiseConv2d ops
    // vanish entirely from a leave-out vocabulary).
    out.push_str(&check(
        "the headline unique-op model gains >= 8.3% from clustering",
        unique_models.iter().map(|m| improvements[m]).fold(f64::NEG_INFINITY, f64::max) >= 8.3,
    ));
    // Note: unlike the paper, our common-op models also benefit broadly —
    // clustering's dimensionality reduction conditions the RF/DNN members
    // on this smaller corpus (documented in EXPERIMENTS.md).
    out.push_str(&check(
        "clustering does not hurt common-op models badly",
        common_avg > -10.0,
    ));
    Ok(out)
}

