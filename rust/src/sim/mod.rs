//! GPU training simulator: the testbed substitute for the paper's AWS/IBM
//! instances (DESIGN.md §2).
//!
//! [`cost_model`] assigns each op a latency from a roofline + utilization
//! model parameterized by [`crate::gpu::GpuSpec`]; [`execute`] runs a whole
//! graph producing ground-truth batch latency and the profiler view;
//! [`workload`] enumerates the paper's G x M x B x P corpus with OOM /
//! model-constraint filtering.

pub mod cost_model;
pub mod multigpu;
pub mod workload;

pub use cost_model::{price_per_hour, Pricing, SPOT_PRICE_FRACTION};
pub use multigpu::ScalingTable;
pub use workload::{enumerate_workloads, run_workload, Workload, WorkloadRun, BATCHES, PIXELS};

/// Deep-learning SDK generation (paper Sec VII "modeling train latency on
/// different deep learning frameworks"). Newer stacks dispatch ops with
/// less host overhead and fuse more aggressively.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SdkVersion {
    /// The paper's environment: TF 2.3.0 / CUDA 10.1.
    Tf23,
    /// A newer stack: lower per-op dispatch cost, BN/activation fusion.
    Tf27,
}

use crate::gpu::GpuSpec;
use crate::models::Graph;
use crate::profiler::{OpRecord, Profile};
use crate::util::{seed_of, Rng64};

/// Result of simulating one training step (one mini-batch) on one GPU.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Ground-truth batch latency (profiling off), ms.
    pub batch_latency_ms: f64,
    /// Profiler view (profiling on: ~20-30% inflated, per Sec III-A).
    pub profile: Profile,
    /// Estimated device memory footprint, bytes.
    pub memory_bytes: f64,
}

/// Device memory check — the "hardware constraint" workload filter.
pub fn fits_in_memory(graph: &Graph, gpu: &GpuSpec) -> bool {
    graph.memory_bytes() <= gpu.vram_gib * 1024.0 * 1024.0 * 1024.0 * 0.92
}

/// Simulate one training step of `graph` on `gpu` (TF 2.3 environment).
///
/// Deterministic: measurement noise is keyed on (model, batch, pixels,
/// instance, op index), so repeated calls return identical results.
pub fn execute(graph: &Graph, gpu: &GpuSpec) -> SimResult {
    execute_sdk(graph, gpu, SdkVersion::Tf23)
}

/// Simulate under a specific SDK generation.
pub fn execute_sdk(graph: &Graph, gpu: &GpuSpec, sdk: SdkVersion) -> SimResult {
    let seed = seed_of(&[
        graph.model.name(),
        &graph.batch.to_string(),
        &graph.pixels.to_string(),
        gpu.instance.key(),
    ]);
    let mut rng = Rng64::new(seed);

    let mut records = Vec::with_capacity(graph.ops.len());
    let mut clean_total_ms = 0.0;
    let mut profiled_total_ms = 0.0;

    // Profiling overhead: a global slowdown factor in the paper's observed
    // 20-30% band (deterministic per workload), plus a tiny per-op tax.
    let prof_factor = 1.2 + 0.1 * rng.next_f64();

    // SDK effects: newer stacks cut host dispatch and fuse normalization/
    // activation chains (fewer effective bytes + kernel launches).
    let (dispatch_scale, fused_scale) = match sdk {
        SdkVersion::Tf23 => (1.0, 1.0),
        SdkVersion::Tf27 => (0.62, 0.72),
    };

    for op in &graph.ops {
        let mut base_us = cost_model::op_latency_us(op, gpu);
        let overhead = gpu.launch_overhead_us + gpu.framework_overhead_us;
        base_us = (base_us - overhead) + overhead * dispatch_scale;
        if matches!(
            op.class,
            crate::ops::OpClass::Normalization | crate::ops::OpClass::Elementwise
        ) {
            base_us *= fused_scale;
        }
        // measurement noise ~ lognormal, sigma ~3%
        let noise = (rng.normal() * 0.03).exp();
        let clean_us = base_us * noise;
        let profiled_us = clean_us * prof_factor + 2.0;
        clean_total_ms += clean_us / 1000.0;
        profiled_total_ms += profiled_us / 1000.0;
        records.push(OpRecord {
            op_name: op.name.to_string(),
            layer_name: op.layer.clone(),
            output_shape: op.out_shape.clone(),
            mem_kb: op.bytes / 1024.0,
            time_ms: profiled_us / 1000.0,
        });
    }

    // Fixed per-step host overhead: input pipeline, python step loop, H2D
    // copy of the input batch.
    let input_bytes = (graph.batch * graph.pixels * graph.pixels * 3) as f64 * 4.0;
    let h2d_ms = input_bytes / (gpu.pcie_gbs * 1e9) * 1e3;
    let step_overhead_ms = 1.0 + h2d_ms;
    clean_total_ms += step_overhead_ms;
    profiled_total_ms += step_overhead_ms * prof_factor;

    SimResult {
        batch_latency_ms: clean_total_ms,
        profile: Profile {
            records,
            batch_latency_profiled_ms: profiled_total_ms,
        },
        memory_bytes: graph.memory_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Instance;
    use crate::models::{build, ModelId};

    #[test]
    fn deterministic() {
        let g = build(ModelId::ResNet18, 16, 64).unwrap();
        let a = execute(&g, Instance::P3.spec());
        let b = execute(&g, Instance::P3.spec());
        assert_eq!(a.batch_latency_ms, b.batch_latency_ms);
        assert_eq!(
            a.profile.batch_latency_profiled_ms,
            b.profile.batch_latency_profiled_ms
        );
    }

    #[test]
    fn profiling_overhead_in_band() {
        let g = build(ModelId::Vgg16, 16, 128).unwrap();
        for i in Instance::CORE {
            let r = execute(&g, i.spec());
            let ratio = r.profile.batch_latency_profiled_ms / r.batch_latency_ms;
            assert!((1.15..1.40).contains(&ratio), "{i}: overhead ratio {ratio}");
        }
    }

    #[test]
    fn faster_gpu_for_big_models() {
        // AlexNet (big dense matmuls): p3 must beat p2 clearly (Fig 2a
        // shows ~10x between best and worst).
        let g = build(ModelId::AlexNet, 16, 32).unwrap();
        let p3 = execute(&g, Instance::P3.spec()).batch_latency_ms;
        let p2 = execute(&g, Instance::P2.spec()).batch_latency_ms;
        assert!(p3 < p2 / 2.0, "p3 {p3} vs p2 {p2}");
    }

    #[test]
    fn tiny_model_not_fastest_on_v100() {
        // LeNet5 is overhead-dominated: g4dn (low launch+framework
        // overhead) wins over p2 but p3 is NOT 10x faster (Fig 2a).
        let g = build(ModelId::LeNet5, 16, 32).unwrap();
        let g4 = execute(&g, Instance::G4dn.spec()).batch_latency_ms;
        let p2 = execute(&g, Instance::P2.spec()).batch_latency_ms;
        let p3 = execute(&g, Instance::P3.spec()).batch_latency_ms;
        assert!(g4 < p2, "g4dn should beat p2 on LeNet5");
        assert!(p3 / g4 < 2.0 && g4 / p3 < 2.0, "tiny model: g4dn~p3");
    }

    #[test]
    fn batch_scaling_sublinear_on_v100() {
        // Fig 2c: MobileNetV2 @32px on p3, 16->256 batch only ~1.4-3x.
        let g16 = build(ModelId::MobileNetV2, 16, 32).unwrap();
        let g256 = build(ModelId::MobileNetV2, 256, 32).unwrap();
        let t16 = execute(&g16, Instance::P3.spec()).batch_latency_ms;
        let t256 = execute(&g256, Instance::P3.spec()).batch_latency_ms;
        let ratio = t256 / t16;
        assert!(ratio < 6.0, "p3 mobilenet batch scaling {ratio}");
        // while VGG13 @128 on g4dn is closer to linear (paper: 13.5x)
        let v16 = build(ModelId::Vgg13, 16, 128).unwrap();
        let v256 = build(ModelId::Vgg13, 256, 128).unwrap();
        let s16 = execute(&v16, Instance::G4dn.spec()).batch_latency_ms;
        let s256 = execute(&v256, Instance::G4dn.spec()).batch_latency_ms;
        let vratio = s256 / s16;
        assert!(vratio > 8.0, "g4dn vgg13 batch scaling {vratio}");
        assert!(vratio > ratio);
    }

    #[test]
    fn oom_filter_catches_big_workloads() {
        // VGG16, 256px, batch 256: activations alone blow past 8-16GB.
        let g = build(ModelId::Vgg16, 256, 256).unwrap();
        assert!(!fits_in_memory(&g, Instance::G3s.spec()));
        let small = build(ModelId::LeNet5, 16, 32).unwrap();
        assert!(fits_in_memory(&small, Instance::G3s.spec()));
    }
}
