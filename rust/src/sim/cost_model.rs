//! Per-operation roofline + utilization cost model.
//!
//! latency(op) = launch + framework + max(compute, memory)
//!
//! * compute = flops / (peak_flops · class_eff · tc_boost · utilization)
//! * memory  = bytes / (bandwidth · mem_eff)
//! * utilization = p / (p + saturation): wide devices need more parallel
//!   work to saturate, which produces the non-linear batch scaling of
//!   Fig 2c (V100 barely slows down from batch 16 → 256 on small nets).
//!
//! Class efficiencies approximate cuDNN-era measured fractions of peak:
//! dense conv/GEMM run at 45-65% of peak FLOPs, depthwise conv is
//! bandwidth-bound, elementwise ops are pure-bandwidth.

use crate::gpu::{GpuSpec, Instance};
use crate::ops::{Op, OpClass};

/// Purchase option for cloud price scenarios (the advisor's
/// spot-vs-on-demand axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Pricing {
    OnDemand,
    Spot,
}

impl Pricing {
    pub const ALL: [Pricing; 2] = [Pricing::OnDemand, Pricing::Spot];

    pub fn key(self) -> &'static str {
        match self {
            Pricing::OnDemand => "on_demand",
            Pricing::Spot => "spot",
        }
    }

    pub fn from_key(key: &str) -> Option<Pricing> {
        Pricing::ALL.into_iter().find(|p| p.key() == key)
    }
}

/// Fraction of the on-demand price paid for spot capacity — the historical
/// 60-70% discount band for GPU instance families, folded to one constant
/// (spot markets move; the advisor models the scenario, not the tape).
pub const SPOT_PRICE_FRACTION: f64 = 0.34;

/// $/hour for `n_gpus` GPUs of an instance family under a purchase option.
/// Multi-GPU nodes price linearly in GPU count, matching the AWS ladder
/// (e.g. p3.8xlarge = 4 x p3.2xlarge within a percent).
pub fn price_per_hour(instance: Instance, pricing: Pricing, n_gpus: usize) -> f64 {
    let base = instance.spec().price_hr * n_gpus as f64;
    match pricing {
        Pricing::OnDemand => base,
        Pricing::Spot => base * SPOT_PRICE_FRACTION,
    }
}

/// Fraction of peak FP32 FLOPs a fully-utilized kernel of this class
/// achieves (cuDNN/cuBLAS measured ballparks).
fn class_compute_eff(class: OpClass) -> f64 {
    match class {
        OpClass::MatrixCompute => 0.55,
        OpClass::Depthwise => 0.12,
        OpClass::Normalization => 0.10,
        OpClass::Pooling => 0.08,
        OpClass::Elementwise => 0.05,
        OpClass::Reduction => 0.06,
        OpClass::DataMovement => 0.02,
        OpClass::Optimizer => 0.05,
    }
}

/// Fraction of peak memory bandwidth achieved per class.
fn class_mem_eff(class: OpClass) -> f64 {
    match class {
        OpClass::MatrixCompute => 0.75,
        OpClass::Depthwise => 0.70,
        OpClass::Normalization => 0.80,
        OpClass::Pooling => 0.75,
        OpClass::Elementwise => 0.85,
        OpClass::Reduction => 0.70,
        OpClass::DataMovement => 0.85,
        OpClass::Optimizer => 0.80,
    }
}

/// Tensor-core style speedup for dense conv/GEMM on TC devices (cuDNN
/// autotuned mixed/TF32 paths — modest, not the marketing 8x).
fn tc_boost(op: &Op, gpu: &GpuSpec) -> f64 {
    if gpu.tensor_cores && op.class == OpClass::MatrixCompute {
        1.6
    } else {
        1.0
    }
}

/// Occupancy/utilization in (0, 1]: saturating curve over the number of
/// parallel work items.
pub fn utilization(op: &Op, gpu: &GpuSpec) -> f64 {
    let p = op.out_elems.max(1.0);
    let p = match op.class {
        // matrix ops expose more parallelism than their output count (the
        // reduction dimension is tiled across SMs too).
        OpClass::MatrixCompute => (p * (op.flops / p).sqrt()).max(p),
        // reductions parallelize over their *inputs* (tree reduction), not
        // their (often scalar) outputs.
        OpClass::Reduction => p.max(op.flops / 4.0),
        _ => p,
    };
    // floor: even a one-thread kernel keeps one SM partially busy rather
    // than stretching per-element cost to the whole device's reciprocal.
    (p / (p + gpu.saturation_elems)).max(1.0 / 1024.0)
}

/// Deterministic per-(op kind, layer arithmetic-intensity bucket)
/// efficiency wiggle in [0.85, 1.18] — the kernel-selection effect: the
/// library's chosen algorithm for a given layer *shape* achieves a
/// shape-specific fraction of peak that no closed-form model captures.
/// Deliberately keyed on the shape only (NOT the device): profiled
/// features absorb it, the cross-instance mapping stays smooth (Fig 9/10),
/// while analytic models (Paleo/MLPredict) mispredict per-shape by
/// construction. Keyed on flops-per-output, which is constant across
/// batch and pixel changes for a fixed layer width/kernel.
fn algo_selection_factor(op: &Op) -> f64 {
    let intensity_bucket = ((op.flops / op.out_elems.max(1.0) + 1.0).log2() * 2.0) as i64;
    let h = crate::util::seed_of(&[op.name, &intensity_bucket.to_string()]);
    let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
    0.85 + 0.33 * unit
}

/// Latency of one op on one GPU, microseconds. Pure function (no noise).
pub fn op_latency_us(op: &Op, gpu: &GpuSpec) -> f64 {
    let util = utilization(op, gpu);
    let eff = class_compute_eff(op.class) * tc_boost(op, gpu) * util * algo_selection_factor(op);
    let compute_us = op.flops / (gpu.tflops_fp32 * 1e12 * eff) * 1e6;
    let mem_us = op.bytes / (gpu.mem_bw_gbs * 1e9 * class_mem_eff(op.class)) * 1e6;
    gpu.launch_overhead_us + gpu.framework_overhead_us + compute_us.max(mem_us)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Instance;
    use crate::ops::{Op, OpClass};

    fn conv_op(flops: f64, elems: usize) -> Op {
        Op::new(
            "Conv2D",
            "conv2d_0",
            OpClass::MatrixCompute,
            flops,
            flops / 10.0,
            vec![elems],
        )
    }

    #[test]
    fn pricing_keys_roundtrip() {
        for p in Pricing::ALL {
            assert_eq!(Pricing::from_key(p.key()), Some(p));
        }
        assert_eq!(Pricing::from_key("reserved"), None);
    }

    #[test]
    fn price_per_hour_scales() {
        let od1 = price_per_hour(Instance::P3, Pricing::OnDemand, 1);
        assert_eq!(od1, Instance::P3.spec().price_hr);
        assert_eq!(price_per_hour(Instance::P3, Pricing::OnDemand, 4), 4.0 * od1);
        let spot = price_per_hour(Instance::P3, Pricing::Spot, 1);
        assert!(spot < od1 && spot > 0.0);
        assert_eq!(spot, od1 * SPOT_PRICE_FRACTION);
    }

    #[test]
    fn overhead_floor() {
        // A near-empty op costs at least launch + framework overhead.
        let op = Op::new("Relu", "a", OpClass::Elementwise, 10.0, 40.0, vec![10]);
        let g = Instance::P2.spec();
        let t = op_latency_us(&op, g);
        assert!(t >= g.launch_overhead_us + g.framework_overhead_us);
        assert!(t < g.launch_overhead_us + g.framework_overhead_us + 1.0);
    }

    #[test]
    fn big_conv_faster_on_v100() {
        let op = conv_op(1e10, 1_000_000);
        let t_p3 = op_latency_us(&op, Instance::P3.spec());
        let t_p2 = op_latency_us(&op, Instance::P2.spec());
        // V100 has 3.4x the FLOPs + tensor cores
        assert!(t_p2 / t_p3 > 3.0, "p2/p3 = {}", t_p2 / t_p3);
    }

    #[test]
    fn utilization_monotone_in_work() {
        let g = Instance::P3.spec();
        let small = conv_op(1e6, 1_000);
        let big = conv_op(1e9, 1_000_000);
        assert!(utilization(&small, g) < utilization(&big, g));
        assert!(utilization(&big, g) <= 1.0);
    }

    #[test]
    fn v100_less_saturated_than_m60_on_same_op() {
        // The Fig 2c mechanism: same small op uses a smaller fraction of a
        // wider device.
        let op = conv_op(1e7, 20_000);
        assert!(
            utilization(&op, Instance::P3.spec()) < utilization(&op, Instance::G3s.spec())
        );
    }

    #[test]
    fn bandwidth_bound_ops_track_bandwidth() {
        let op = Op::new(
            "Relu",
            "a",
            OpClass::Elementwise,
            1e6,
            4e8, // 400MB moved
            vec![100_000_000],
        );
        let t_p3 = op_latency_us(&op, Instance::P3.spec()); // 900 GB/s
        let t_g3 = op_latency_us(&op, Instance::G3s.spec()); // 160 GB/s
        let ratio = t_g3 / t_p3;
        assert!(ratio > 3.0, "bandwidth ratio should dominate: {ratio}");
    }
}
