//! Workload corpus: the paper's G x M x B x P Cartesian product with
//! hardware (OOM) and model-constraint filtering (Sec III: 1500 → 1228
//! executable workloads).

use crate::gpu::Instance;
use crate::models::{build, Graph, ModelId};
use crate::profiler::Profile;
use crate::sim;

/// The paper's batch sizes B.
pub const BATCHES: [usize; 5] = [16, 32, 64, 128, 256];
/// The paper's input pixel sizes P (side length; images are p x p x 3).
pub const PIXELS: [usize; 5] = [32, 64, 128, 224, 256];

/// One (model, batch, pixels) training configuration — the paper's `mbp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Workload {
    pub model: ModelId,
    pub batch: usize,
    pub pixels: usize,
}

impl Workload {
    pub fn new(model: ModelId, batch: usize, pixels: usize) -> Self {
        Self {
            model,
            batch,
            pixels,
        }
    }

    pub fn key(&self) -> String {
        format!("{}/b{}/p{}", self.model.name(), self.batch, self.pixels)
    }

    /// Build the op graph (Err = model constraint).
    pub fn graph(&self) -> Result<Graph, crate::models::BuildError> {
        build(self.model, self.batch, self.pixels)
    }
}

/// A workload executed on one instance: the simulator's observation.
#[derive(Debug, Clone)]
pub struct WorkloadRun {
    pub workload: Workload,
    pub instance: Instance,
    /// Ground-truth batch latency (profiling off), ms — the paper's y.
    pub latency_ms: f64,
    /// Profiler view (profiling on) — the paper's feature source x.
    pub profile: Profile,
}

/// Enumerate every executable (workload, instance) pair over the given
/// instance set: the offline experiment design of Sec III.
///
/// A workload is kept for an instance iff the model accepts the input size
/// AND the training step fits in that instance's device memory.
pub fn enumerate_workloads(instances: &[Instance]) -> Vec<(Workload, Vec<Instance>)> {
    let mut out = Vec::new();
    for model in ModelId::ALL {
        for batch in BATCHES {
            for pixels in PIXELS {
                let w = Workload::new(model, batch, pixels);
                let graph = match w.graph() {
                    Ok(g) => g,
                    Err(_) => continue, // model constraint
                };
                let fitting: Vec<Instance> = instances
                    .iter()
                    .copied()
                    .filter(|i| sim::fits_in_memory(&graph, i.spec()))
                    .collect();
                if !fitting.is_empty() {
                    out.push((w, fitting));
                }
            }
        }
    }
    out
}

/// Execute one workload on one instance (simulator substitute for an EC2
/// training run). Deterministic.
pub fn run_workload(w: &Workload, instance: Instance) -> Option<WorkloadRun> {
    run_workload_sdk(w, instance, sim::SdkVersion::Tf23)
}

/// Execute under a specific SDK generation (Sec VII extension).
pub fn run_workload_sdk(
    w: &Workload,
    instance: Instance,
    sdk: sim::SdkVersion,
) -> Option<WorkloadRun> {
    let graph = w.graph().ok()?;
    if !sim::fits_in_memory(&graph, instance.spec()) {
        return None;
    }
    let r = sim::execute_sdk(&graph, instance.spec(), sdk);
    Some(WorkloadRun {
        workload: *w,
        instance,
        latency_ms: r.batch_latency_ms,
        profile: r.profile,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_size_near_paper() {
        // Paper: 1228 of 1500 G x M x B x P cases executable. Our
        // simulator's filters should land in the same band. Count
        // (workload, instance) pairs over the 4 core instances.
        let ws = enumerate_workloads(&Instance::CORE);
        let pairs: usize = ws.iter().map(|(_, is)| is.len()).sum();
        assert!(
            (1000..=1500).contains(&pairs),
            "corpus size {pairs} outside plausible band"
        );
        // and strictly fewer than the full product (filters are active)
        assert!(pairs < 15 * 5 * 5 * 4);
    }

    #[test]
    fn run_workload_none_for_oom() {
        let w = Workload::new(ModelId::Vgg16, 256, 256);
        assert!(run_workload(&w, Instance::G3s).is_none());
    }

    #[test]
    fn run_workload_some_and_deterministic() {
        let w = Workload::new(ModelId::ResNet18, 16, 64);
        let a = run_workload(&w, Instance::G4dn).unwrap();
        let b = run_workload(&w, Instance::G4dn).unwrap();
        assert_eq!(a.latency_ms, b.latency_ms);
        assert!(a.latency_ms > 0.0);
        assert!(!a.profile.records.is_empty());
    }

    #[test]
    fn distinct_op_count_near_paper() {
        // The paper aggregates 65 high-level operations across the corpus;
        // our vocabulary is the same order of magnitude.
        use std::collections::BTreeSet;
        let mut names: BTreeSet<String> = BTreeSet::new();
        for model in ModelId::ALL {
            if let Ok(g) = build(model, 16, 224) {
                for op in g.ops {
                    names.insert(op.name.to_string());
                }
            }
        }
        assert!(
            (25..=70).contains(&names.len()),
            "distinct ops {}",
            names.len()
        );
    }
}
