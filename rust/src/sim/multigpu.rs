//! Multi-GPU data-parallel training simulation + the static-multiplier
//! predictor (paper Sec VII, citing Hafeez et al.: "as more GPUs are added
//! for CNN training, the performance gain ratio becomes more static,
//! regardless of GPU instance type").
//!
//! Data-parallel step: per-GPU compute on batch/N + ring all-reduce of the
//! gradients over the node interconnect + a per-step synchronization tax.

use crate::gpu::{GpuSpec, Instance};
use crate::models::ModelId;
use crate::sim::{self, Workload};

/// Intra-node GPU interconnect bandwidth, GB/s (NVLink on p3, PCIe peer
/// transfers elsewhere).
fn interconnect_gbs(gpu: &GpuSpec) -> f64 {
    match gpu.instance {
        Instance::P3 => 150.0, // NVLink
        Instance::G5 => 64.0,  // PCIe gen4
        _ => gpu.pcie_gbs,     // PCIe peer-to-peer
    }
}

/// Simulated data-parallel step latency (ms) for `n_gpus` on one node.
/// The *global* batch is split evenly; returns None when the per-GPU
/// shard is not executable (model constraint / too-small shard / OOM).
pub fn multi_gpu_latency(
    model: ModelId,
    global_batch: usize,
    pixels: usize,
    instance: Instance,
    n_gpus: usize,
) -> Option<f64> {
    assert!(n_gpus >= 1);
    if global_batch % n_gpus != 0 {
        return None;
    }
    let shard = global_batch / n_gpus;
    if shard == 0 {
        return None;
    }
    let w = Workload::new(model, shard, pixels);
    let graph = w.graph().ok()?;
    let gpu = instance.spec();
    if !sim::fits_in_memory(&graph, gpu) {
        return None;
    }
    let compute_ms = sim::execute(&graph, gpu).batch_latency_ms;
    if n_gpus == 1 {
        return Some(compute_ms);
    }
    // ring all-reduce: each GPU sends/receives 2(N-1)/N of the gradient set
    let grad_bytes = graph.weight_elems * 4.0;
    let allreduce_ms =
        2.0 * (n_gpus as f64 - 1.0) / n_gpus as f64 * grad_bytes / (interconnect_gbs(gpu) * 1e9)
            * 1e3;
    // per-step NCCL launch/sync tax grows with the ring size
    let sync_ms = 0.3 * (n_gpus as f64).log2().max(1.0);
    Some(compute_ms + allreduce_ms + sync_ms)
}

/// Hafeez-style static multiplier: the mean latency ratio
/// `t(N gpus, global batch B) / t(1 gpu, B)` measured over a calibration
/// model set, per (instance, N). PROFET predicts the 1-GPU latency; the
/// multiplier extends it to N GPUs.
///
/// A calibration model contributes a ratio only when BOTH its 1-GPU and
/// its N-GPU run are executable on `instance`; a model that fails either
/// side (e.g. its single-GPU shard OOMs on a small-memory instance) is
/// *skipped*, exactly like the N-GPU branch — it must not veto the whole
/// (instance, N) pair. The result is `None` only when no calibration
/// model produced a ratio: at least one model must run at both ends for
/// the multiplier to exist.
pub fn static_multiplier(
    instance: Instance,
    n_gpus: usize,
    calibration: &[(ModelId, usize, usize)],
) -> Option<f64> {
    let mut ratios = Vec::new();
    for &(m, b, p) in calibration {
        let Some(t1) = multi_gpu_latency(m, b, p, instance, 1) else {
            continue;
        };
        if let Some(tn) = multi_gpu_latency(m, b, p, instance, n_gpus) {
            ratios.push(tn / t1);
        }
    }
    if ratios.is_empty() {
        None
    } else {
        Some(crate::util::mean(&ratios))
    }
}

/// Default calibration workload set for [`static_multiplier`]: mid-size
/// classics that fit every instance at b=128/p=64.
pub const CALIBRATION: [(ModelId, usize, usize); 3] = [
    (ModelId::ResNet18, 128, 64),
    (ModelId::ResNet34, 128, 64),
    (ModelId::Vgg11, 128, 64),
];

/// Memoizing per-(instance, N) static-multiplier table. Computing one
/// entry simulates the whole calibration set, so long-lived holders (the
/// serving engine, the advisor) reuse entries across sweeps. Thread-safe.
#[derive(Debug, Default)]
pub struct ScalingTable {
    memo: std::sync::Mutex<std::collections::BTreeMap<(Instance, usize), Option<f64>>>,
}

impl ScalingTable {
    pub fn new() -> ScalingTable {
        ScalingTable::default()
    }

    /// `t(N gpus, global batch B) / t(1 gpu, B)` for the calibration set;
    /// exactly 1.0 for N=1, `None` when no calibration workload runs.
    pub fn multiplier(&self, instance: Instance, n_gpus: usize) -> Option<f64> {
        if n_gpus == 1 {
            return Some(1.0);
        }
        *self
            .memo
            .lock()
            .unwrap()
            .entry((instance, n_gpus))
            .or_insert_with(|| static_multiplier(instance, n_gpus, &CALIBRATION))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_gpu_matches_plain_execute() {
        let t1 = multi_gpu_latency(ModelId::ResNet18, 64, 64, Instance::P3, 1).unwrap();
        let w = Workload::new(ModelId::ResNet18, 64, 64);
        let plain = sim::run_workload(&w, Instance::P3).unwrap().latency_ms;
        assert!((t1 - plain).abs() < 1e-9);
    }

    #[test]
    fn scaling_is_sublinear_speedup() {
        // 4 GPUs never reach 4x and never get slower than 1 GPU for big jobs
        let t1 = multi_gpu_latency(ModelId::Vgg16, 128, 64, Instance::P3, 1).unwrap();
        let t4 = multi_gpu_latency(ModelId::Vgg16, 128, 64, Instance::P3, 4).unwrap();
        let speedup = t1 / t4;
        assert!(speedup > 1.5 && speedup < 4.0, "speedup {speedup}");
    }

    #[test]
    fn vgg16_oom_shard_rejected() {
        // VGG16 b128@128px keeps ~14 GB of activations: no fit on 16 GB.
        assert!(multi_gpu_latency(ModelId::Vgg16, 128, 128, Instance::P3, 1).is_none());
        // splitting across 4 GPUs shrinks the shard and it fits again
        assert!(multi_gpu_latency(ModelId::Vgg16, 128, 128, Instance::P3, 4).is_some());
    }

    #[test]
    fn nvlink_beats_pcie_on_allreduce_heavy_models() {
        // AlexNet: 60M params (244 MB gradients) but little compute — the
        // all-reduce dominates, so the interconnect decides the scaling.
        let p3 = {
            let t1 = multi_gpu_latency(ModelId::AlexNet, 128, 32, Instance::P3, 1).unwrap();
            let t4 = multi_gpu_latency(ModelId::AlexNet, 128, 32, Instance::P3, 4).unwrap();
            t1 / t4
        };
        let g3s = {
            let t1 = multi_gpu_latency(ModelId::AlexNet, 128, 32, Instance::G3s, 1).unwrap();
            let t4 = multi_gpu_latency(ModelId::AlexNet, 128, 32, Instance::G3s, 4).unwrap();
            t1 / t4
        };
        assert!(p3 > g3s, "NVLink scaling {p3} vs PCIe {g3s}");
    }

    #[test]
    fn indivisible_batch_rejected() {
        assert!(multi_gpu_latency(ModelId::ResNet18, 100, 64, Instance::P3, 3).is_none());
    }

    #[test]
    fn static_multiplier_near_measured_ratio() {
        let cal = [
            (ModelId::ResNet18, 128usize, 64usize),
            (ModelId::ResNet34, 128, 64),
            (ModelId::Vgg11, 128, 64),
        ];
        let m = static_multiplier(Instance::P3, 2, &cal).unwrap();
        assert!(m > 0.4 && m < 1.1, "2-gpu multiplier {m}");
    }

    #[test]
    fn unexecutable_calibration_model_is_skipped_not_fatal() {
        // VGG16 b128@128px OOMs at 1 GPU on p3 (see vgg16_oom_shard_rejected)
        // — it must be skipped, not abort the whole multiplier via `?`
        let with_oom = [
            (ModelId::Vgg16, 128usize, 128usize), // 1-GPU side not executable
            (ModelId::ResNet18, 128, 64),
        ];
        let only_good = [(ModelId::ResNet18, 128usize, 64usize)];
        let m_mixed = static_multiplier(Instance::P3, 4, &with_oom)
            .expect("one failing calibration model vetoed the whole pair");
        let m_good = static_multiplier(Instance::P3, 4, &only_good).unwrap();
        // the failing model contributed nothing: the mean is over the
        // surviving models only
        assert_eq!(m_mixed.to_bits(), m_good.to_bits());
        // when NO calibration model runs at both ends, there is no ratio
        assert!(static_multiplier(Instance::P3, 4, &[(ModelId::Vgg16, 128, 128)]).is_none());
    }

    #[test]
    fn scaling_table_matches_direct_and_memoizes() {
        let table = ScalingTable::new();
        assert_eq!(table.multiplier(Instance::P3, 1), Some(1.0));
        let via_table = table.multiplier(Instance::P3, 2);
        assert_eq!(via_table, static_multiplier(Instance::P3, 2, &CALIBRATION));
        // second lookup returns the memoized value
        assert_eq!(table.multiplier(Instance::P3, 2), via_table);
        assert_eq!(table.memo.lock().unwrap().len(), 1);
    }
}
