//! Paleo (Qi et al., ICLR 2017): analytic white-box performance model.
//!
//! Per layer: t = flops / (peak_flops · PPP) + bytes / mem_bw, summed over
//! the training step. PPP ("platform percent of peak") is a single global
//! constant — Paleo has no notion of per-op-class efficiency, kernel
//! launch overhead, framework dispatch cost, or utilization ramps, which
//! is exactly why its predictions drift on a real framework (Table III).

use crate::gpu::GpuSpec;
use crate::models::Graph;

/// Paleo's single platform-percent-of-peak constant (the paper's fitted
/// values cluster around 0.5-0.6 for cuDNN-era GPUs).
pub const PPP: f64 = 0.55;

/// Predicted training-step latency (ms) for a graph on a device.
pub fn predict(graph: &Graph, gpu: &GpuSpec) -> f64 {
    let mut total_us = 0.0;
    for op in &graph.ops {
        let compute_us = op.flops / (gpu.tflops_fp32 * 1e12 * PPP) * 1e6;
        let mem_us = op.bytes / (gpu.mem_bw_gbs * 1e9) * 1e6;
        // Paleo sums compute and IO (no overlap modeling for single-GPU)
        total_us += compute_us + mem_us;
    }
    total_us / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Instance;
    use crate::models::{build, ModelId};
    use crate::sim;

    #[test]
    fn underestimates_overhead_dominated_models() {
        // LeNet5 is framework-overhead dominated: Paleo (no overhead term)
        // must underestimate the simulator's ground truth badly.
        let g = build(ModelId::LeNet5, 16, 32).unwrap();
        let truth = sim::execute(&g, Instance::P3.spec()).batch_latency_ms;
        let paleo = predict(&g, Instance::P3.spec());
        assert!(paleo < truth * 0.5, "paleo {paleo} vs truth {truth}");
    }

    #[test]
    fn closer_on_compute_dominated_models() {
        // VGG16 at 224px is GEMM-dominated; the analytic model lands within
        // a factor ~2 of ground truth.
        let g = build(ModelId::Vgg16, 64, 224).unwrap();
        let truth = sim::execute(&g, Instance::P3.spec()).batch_latency_ms;
        let paleo = predict(&g, Instance::P3.spec());
        let ratio = paleo / truth;
        assert!((0.3..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn scales_with_device_flops() {
        let g = build(ModelId::Vgg16, 64, 224).unwrap();
        let p2 = predict(&g, Instance::P2.spec());
        let p3 = predict(&g, Instance::P3.spec());
        assert!(p3 < p2, "faster device predicts faster");
    }
}
