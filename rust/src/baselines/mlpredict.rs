//! MLPredict (Justus et al., IEEE Big Data 2018): learned white-box model.
//!
//! Per-layer features (FLOPs, bytes, output elements, batch size) feed a
//! per-(device, op-class) regression whose per-layer predictions are
//! summed. Faithful to the original's key limitation: it was trained and
//! validated on *small* batch sizes (1-16), so we train on the corpus's
//! small-batch workloads only and let it extrapolate — reproducing the
//! Table IV error blow-up at batch 128+.

use crate::gpu::Instance;
use crate::ml::{FeatureMatrix, LinearRegression};
use crate::models::Graph;
use crate::ops::{Op, OpClass};
use crate::sim::{self, Workload};
use anyhow::Result;
use std::collections::BTreeMap;

/// Largest batch size included in training (the original paper's regime).
pub const TRAIN_BATCH_CAP: usize = 32;

fn class_key(c: OpClass) -> &'static str {
    match c {
        OpClass::MatrixCompute => "matrix",
        OpClass::Depthwise => "depthwise",
        OpClass::Elementwise => "elementwise",
        OpClass::Pooling => "pooling",
        OpClass::Normalization => "norm",
        OpClass::Reduction => "reduction",
        OpClass::DataMovement => "data",
        OpClass::Optimizer => "optimizer",
    }
}

/// Layer-configuration features as in the original: batch size enters as
/// its own (additive) regressor next to per-sample layer dimensions. This
/// is the faithful weakness — per-op cost actually scales ~multiplicatively
/// with batch, which a linear model trained on b <= 32 cannot extrapolate
/// (the Table IV blow-up at b >= 128).
fn op_features(op: &Op, batch: usize) -> Vec<f64> {
    let b = batch as f64;
    vec![
        b,
        op.flops / b / 1e8,
        op.bytes / b / 1e8,
        op.out_elems / b / 1e5,
    ]
}

/// Per-target-device MLPredict model.
pub struct MlPredict {
    target: Instance,
    /// per op-class regressor over op features → per-op microseconds.
    class_models: BTreeMap<&'static str, LinearRegression>,
    /// fallback mean per-op time for unseen classes.
    fallback_us: f64,
}

impl MlPredict {
    /// Train on all executable small-batch workloads for `target`,
    /// using the simulator's per-op latencies as the per-layer labels the
    /// original gathered with its layer-wise benchmark harness.
    pub fn fit(target: Instance, workloads: &[Workload]) -> Result<MlPredict> {
        let mut by_class: BTreeMap<&'static str, (Vec<Vec<f64>>, Vec<f64>)> = BTreeMap::new();
        let mut all_times = Vec::new();
        for w in workloads {
            if w.batch > TRAIN_BATCH_CAP {
                continue;
            }
            let Ok(graph) = w.graph() else { continue };
            if !sim::fits_in_memory(&graph, target.spec()) {
                continue;
            }
            for op in &graph.ops {
                let t_us = sim::cost_model::op_latency_us(op, target.spec());
                let (xs, ys) = by_class.entry(class_key(op.class)).or_default();
                xs.push(op_features(op, w.batch));
                ys.push(t_us);
                all_times.push(t_us);
            }
        }
        anyhow::ensure!(!all_times.is_empty(), "no training workloads");
        let mut class_models = BTreeMap::new();
        for (k, (xs, ys)) in &by_class {
            if xs.len() >= 8 {
                let fit = FeatureMatrix::from_rows(xs).and_then(|m| LinearRegression::fit(&m, ys));
                if let Ok(m) = fit {
                    class_models.insert(*k, m);
                }
            }
        }
        Ok(MlPredict {
            target,
            class_models,
            fallback_us: crate::util::mean(&all_times),
        })
    }

    /// Predict a training-step latency (ms) for a graph at its batch size.
    pub fn predict(&self, graph: &Graph) -> f64 {
        let mut total_us = 0.0;
        for op in &graph.ops {
            let t = match self.class_models.get(class_key(op.class)) {
                Some(m) => m.predict_one(&op_features(op, graph.batch)),
                None => self.fallback_us,
            };
            // negative extrapolations clamp to the fallback floor
            total_us += if t > 0.0 { t } else { self.fallback_us };
        }
        total_us / 1000.0
    }

    pub fn target(&self) -> Instance {
        self.target
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build, ModelId};

    fn small_batch_workloads() -> Vec<Workload> {
        let mut ws = Vec::new();
        for m in [ModelId::Vgg16, ModelId::ResNet18, ModelId::AlexNet, ModelId::MobileNetV2] {
            for b in [16usize, 32] {
                for p in [32usize, 64, 128] {
                    ws.push(Workload::new(m, b, p));
                }
            }
        }
        ws
    }

    #[test]
    fn reasonable_at_small_batch_degrades_at_large() {
        let model = MlPredict::fit(Instance::P3, &small_batch_workloads()).unwrap();
        let err_at = |b: usize| -> f64 {
            let g = build(ModelId::Vgg16, b, 128).unwrap();
            let truth = sim::execute(&g, Instance::P3.spec()).batch_latency_ms;
            (model.predict(&g) - truth).abs() / truth
        };
        let e16 = err_at(16);
        let e256 = err_at(256);
        assert!(e16 < 0.6, "small-batch error {e16}");
        assert!(e256 > e16, "error must grow with batch: {e16} -> {e256}");
    }

    #[test]
    fn fit_requires_data() {
        assert!(MlPredict::fit(Instance::P3, &[]).is_err());
    }
}
