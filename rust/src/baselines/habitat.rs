//! Habitat (Yu et al., USENIX ATC 2021): runtime-based cross-GPU
//! prediction via per-op wave scaling.
//!
//! Habitat takes a *detailed* per-op profile measured on the anchor GPU
//! and scales each kernel's time to the target by the ratio of the
//! relevant hardware resource (compute throughput for math-bound kernels,
//! memory bandwidth for the rest) — much finer-grained input than PROFET's
//! aggregated (op, time) pairs, which is the paper's qualitative critique
//! (needs op-level profiling access). No batch-size change support.

use crate::gpu::{GpuSpec, Instance};
use crate::models::Graph;
use crate::sim;

/// Effective math throughput used for wave-scaling ratios (tensor cores
/// accelerate conv/GEMM, which Habitat models via its MLP; we use the same
/// modest boost the simulator applies).
fn math_throughput(gpu: &GpuSpec) -> f64 {
    gpu.tflops_fp32 * if gpu.tensor_cores { 1.6 } else { 1.0 }
}

/// Predict the target-device latency (ms) by wave-scaling the anchor's
/// per-op simulated profile.
pub fn predict(graph: &Graph, anchor: Instance, target: Instance) -> f64 {
    let a = anchor.spec();
    let t = target.spec();
    let anchor_run = sim::execute(graph, a);
    let mut total_ms = 0.0;
    for (op, rec) in graph.ops.iter().zip(&anchor_run.profile.records) {
        // classify bound-ness from the op's roofline on the ANCHOR device
        // (Habitat does this with measured occupancy/counters).
        let compute_us = op.flops / (math_throughput(a) * 1e12) * 1e6;
        let mem_us = op.bytes / (a.mem_bw_gbs * 1e9) * 1e6;
        let ratio = if compute_us >= mem_us {
            math_throughput(a) / math_throughput(t)
        } else {
            a.mem_bw_gbs / t.mem_bw_gbs
        };
        // profiled time includes profiling overhead; Habitat calibrates it
        // away — approximate by the simulator's known inflation midpoint.
        let clean_ms = rec.time_ms / 1.25;
        total_ms += clean_ms * ratio;
    }
    total_ms + 1.0 // fixed host-side step overhead survives unscaled
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build, ModelId};

    #[test]
    fn t4_to_v100_direction() {
        // V100 is faster: scaling a T4 profile to V100 must shrink it.
        let g = build(ModelId::ResNet50, 32, 224).unwrap();
        let t4 = sim::execute(&g, Instance::G4dn.spec()).batch_latency_ms;
        let pred_v100 = predict(&g, Instance::G4dn, Instance::P3);
        assert!(pred_v100 < t4);
        // and within 2x of the simulator's V100 ground truth
        let truth = sim::execute(&g, Instance::P3.spec()).batch_latency_ms;
        let ratio = pred_v100 / truth;
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn identity_scaling_close_to_truth() {
        // anchor == target: prediction should recover the clean latency
        // up to the profiling-overhead calibration.
        let g = build(ModelId::Vgg13, 16, 128).unwrap();
        let truth = sim::execute(&g, Instance::G3s.spec()).batch_latency_ms;
        let pred = predict(&g, Instance::G3s, Instance::G3s);
        let ratio = pred / truth;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }
}
