//! Comparator baselines (paper Sec V-D): Paleo, MLPredict, Habitat —
//! reimplemented on the same simulator corpus so the accuracy comparison
//! is apples-to-apples.
//!
//! Each baseline reproduces its characteristic failure mode:
//! * **Paleo** — pure analytic FLOPs/bandwidth model; no framework/launch
//!   overhead, one global efficiency → "theoretical modeling cannot
//!   represent the real operation characteristics" (Table III).
//! * **MLPredict** — per-layer linear features trained on *small* batches;
//!   error grows with batch size (Table IV).
//! * **Habitat** — per-op wave scaling of a *detailed* anchor profile;
//!   accurate but needs op-level profiling and supports no batch-size
//!   change (Table V).

pub mod habitat;
pub mod mlpredict;
pub mod paleo;
