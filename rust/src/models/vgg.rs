//! VGG family (Simonyan & Zisserman 2015), configurations A/B/D/E.

use super::builder::{BuildError, Pad, Tape};
use super::{Graph, ModelId};

/// Conv layers per stage (all 3x3), stages separated by 2x2 maxpool.
fn stages(model: ModelId) -> [usize; 5] {
    match model {
        ModelId::Vgg11 => [1, 1, 2, 2, 2],
        ModelId::Vgg13 => [2, 2, 2, 2, 2],
        ModelId::Vgg16 => [2, 2, 3, 3, 3],
        ModelId::Vgg19 => [2, 2, 4, 4, 4],
        _ => unreachable!("not a VGG model"),
    }
}

const WIDTHS: [usize; 5] = [64, 128, 256, 512, 512];

pub fn vgg(model: ModelId, batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(model, batch, pixels);
    for (reps, width) in stages(model).into_iter().zip(WIDTHS) {
        for _ in 0..reps {
            t.conv(3, width, 1, Pad::Same)?.act();
        }
        t.maxpool(2, 2, Pad::Same)?;
    }
    t.dense(4096).act();
    t.dense(4096).act();
    Ok(t.classifier(1000))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_has_13_convs() {
        let g = vgg(ModelId::Vgg16, 1, 224).unwrap();
        let convs = g.ops.iter().filter(|o| o.name == "Conv2D").count();
        assert_eq!(convs, 13);
    }

    #[test]
    fn deeper_vgg_more_flops() {
        let f11 = vgg(ModelId::Vgg11, 16, 128).unwrap().total_flops();
        let f13 = vgg(ModelId::Vgg13, 16, 128).unwrap().total_flops();
        let f16 = vgg(ModelId::Vgg16, 16, 128).unwrap().total_flops();
        let f19 = vgg(ModelId::Vgg19, 16, 128).unwrap().total_flops();
        assert!(f11 < f13 && f13 < f16 && f16 < f19);
    }

    #[test]
    fn vgg_works_at_32px() {
        // 32 / 2^5 = 1 — dense head sits on 1x1x512.
        assert!(vgg(ModelId::Vgg16, 16, 32).is_ok());
    }
}
