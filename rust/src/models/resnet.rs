//! ResNet family (He et al. 2016): basic-block 18/34, bottleneck 50, and
//! the CIFAR-style "ResNetSmall" the paper's corpus includes.

use super::builder::{BuildError, Pad, Tape};
use super::{Graph, ModelId};

/// conv-BN-ReLU helper.
fn cbr(t: &mut Tape, k: usize, c: usize, s: usize) -> Result<(), BuildError> {
    t.conv(k, c, s, Pad::Same)?;
    t.bn().act();
    Ok(())
}

/// Basic residual block: 3x3 conv x2 (+1x1 projection when shape changes).
fn basic_block(t: &mut Tape, c: usize, stride: usize) -> Result<(), BuildError> {
    let needs_proj = stride != 1 || t.channels() != c;
    if needs_proj {
        // projection shortcut runs as a parallel branch
        let ckpt = t.ckpt();
        t.conv(1, c, stride, Pad::Same)?;
        t.bn();
        t.restore(ckpt);
    }
    cbr(t, 3, c, stride)?;
    t.conv(3, c, 1, Pad::Same)?;
    t.bn();
    t.add_residual().act();
    Ok(())
}

/// Bottleneck residual block: 1x1 reduce, 3x3, 1x1 expand (x4).
fn bottleneck(t: &mut Tape, c: usize, stride: usize) -> Result<(), BuildError> {
    let cout = 4 * c;
    let needs_proj = stride != 1 || t.channels() != cout;
    if needs_proj {
        let ckpt = t.ckpt();
        t.conv(1, cout, stride, Pad::Same)?;
        t.bn();
        t.restore(ckpt);
    }
    cbr(t, 1, c, 1)?;
    cbr(t, 3, c, stride)?;
    t.conv(1, cout, 1, Pad::Same)?;
    t.bn();
    t.add_residual().act();
    Ok(())
}

fn imagenet_resnet(
    model: ModelId,
    batch: usize,
    pixels: usize,
    blocks: [usize; 4],
    use_bottleneck: bool,
) -> Result<Graph, BuildError> {
    let mut t = Tape::new(model, batch, pixels);
    cbr(&mut t, 7, 64, 2)?;
    t.maxpool(3, 2, Pad::Same)?;
    let widths = [64usize, 128, 256, 512];
    for (stage, (&n, &c)) in blocks.iter().zip(widths.iter()).enumerate() {
        for b in 0..n {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            if use_bottleneck {
                bottleneck(&mut t, c, stride)?;
            } else {
                basic_block(&mut t, c, stride)?;
            }
        }
    }
    t.gap();
    Ok(t.classifier(1000))
}

pub fn resnet18(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    imagenet_resnet(ModelId::ResNet18, batch, pixels, [2, 2, 2, 2], false)
}

pub fn resnet34(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    imagenet_resnet(ModelId::ResNet34, batch, pixels, [3, 4, 6, 3], false)
}

pub fn resnet50(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    imagenet_resnet(ModelId::ResNet50, batch, pixels, [3, 4, 6, 3], true)
}

/// CIFAR-style small ResNet (3 stages of one basic block, widths 16/32/64).
pub fn resnet_small(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::ResNetSmall, batch, pixels);
    cbr(&mut t, 3, 16, 1)?;
    for (stage, c) in [16usize, 32, 64].into_iter().enumerate() {
        let stride = if stage == 0 { 1 } else { 2 };
        basic_block(&mut t, c, stride)?;
        basic_block(&mut t, c, 1)?;
    }
    t.gap();
    Ok(t.classifier(10))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_vs_34_vs_50_ordering() {
        let f18 = resnet18(16, 224).unwrap().total_flops();
        let f34 = resnet34(16, 224).unwrap().total_flops();
        let f50 = resnet50(16, 224).unwrap().total_flops();
        assert!(f18 < f34, "{f18} !< {f34}");
        assert!(f34 < f50 * 1.3, "34 and 50 comparable");
    }

    #[test]
    fn resnet_small_is_small() {
        let g = resnet_small(16, 32).unwrap();
        assert!(g.weight_elems < 1.0e6, "{}", g.weight_elems);
    }

    #[test]
    fn residual_adds_emitted() {
        let g = resnet18(4, 64).unwrap();
        let adds = g.ops.iter().filter(|o| o.name == "AddV2").count();
        assert_eq!(adds, 8, "8 basic blocks in resnet18");
    }

    #[test]
    fn bn_everywhere() {
        let g = resnet50(4, 64).unwrap();
        assert!(g.ops.iter().filter(|o| o.name == "FusedBatchNormV3").count() > 40);
    }
}
