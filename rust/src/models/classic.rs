//! Classic small/medium CNNs: LeNet5, AlexNet, and the Keras-style
//! MNIST/CIFAR10 example networks the paper includes in its corpus.

use super::builder::{BuildError, Pad, Tape};
use super::{Graph, ModelId};

/// LeNet-5 (LeCun et al. 1998): two valid 5x5 convs with pooling, then
/// 120/84/10 dense stack. ~60k parameters at 32px.
pub fn lenet5(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::LeNet5, batch, pixels);
    t.conv(5, 6, 1, Pad::Valid)?.act();
    t.maxpool(2, 2, Pad::Valid)?;
    t.conv(5, 16, 1, Pad::Valid)?.act();
    t.maxpool(2, 2, Pad::Valid)?;
    t.dense(120).act();
    t.dense(84).act();
    Ok(t.classifier(10))
}

/// AlexNet (Krizhevsky et al. 2012), single-tower variant.
pub fn alexnet(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::AlexNet, batch, pixels);
    t.conv(11, 96, 4, Pad::Same)?.act();
    t.maxpool(3, 2, Pad::Same)?;
    t.conv(5, 256, 1, Pad::Same)?.act();
    t.maxpool(3, 2, Pad::Same)?;
    t.conv(3, 384, 1, Pad::Same)?.act();
    t.conv(3, 384, 1, Pad::Same)?.act();
    t.conv(3, 256, 1, Pad::Same)?.act();
    t.maxpool(3, 2, Pad::Same)?;
    t.dense(4096).act();
    t.dense(4096).act();
    Ok(t.classifier(1000))
}

/// The Keras "MNIST CNN" example: two convs, one pool, dense 128.
pub fn mnist_cnn(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::MnistCnn, batch, pixels);
    t.conv(3, 32, 1, Pad::Valid)?.act();
    t.conv(3, 64, 1, Pad::Valid)?.act();
    t.maxpool(2, 2, Pad::Valid)?;
    t.dense(128).act();
    Ok(t.classifier(10))
}

/// The Keras "CIFAR10 CNN" example: conv32x2 + pool + conv64x2 + pool +
/// dense 512.
pub fn cifar10_cnn(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::Cifar10Cnn, batch, pixels);
    t.conv(3, 32, 1, Pad::Same)?.act();
    t.conv(3, 32, 1, Pad::Valid)?.act();
    t.maxpool(2, 2, Pad::Valid)?;
    t.conv(3, 64, 1, Pad::Same)?.act();
    t.conv(3, 64, 1, Pad::Valid)?.act();
    t.maxpool(2, 2, Pad::Valid)?;
    t.dense(512).act();
    Ok(t.classifier(10))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_params_at_32px() {
        // Classic LeNet-5 has ~61k params at 32px input.
        let g = lenet5(16, 32).unwrap().weight_elems;
        assert!((5.0e4..8.0e4).contains(&g), "{g}");
    }

    #[test]
    fn lenet_rejects_sub_kernel_inputs() {
        assert!(lenet5(16, 8).is_err());
    }

    #[test]
    fn alexnet_dense_dominates_params() {
        let g = alexnet(16, 224).unwrap();
        // dense 9216->4096 alone is 37.7M
        assert!(g.weight_elems > 4.0e7);
    }

    #[test]
    fn mnist_cifar_build_all_pixel_sizes() {
        for p in [32, 64, 128, 224, 256] {
            assert!(mnist_cnn(16, p).is_ok(), "mnist @{p}");
            assert!(cifar10_cnn(16, p).is_ok(), "cifar @{p}");
        }
    }
}
