//! Transformer encoders (the Sec VII "non-CNN models" extension).
//!
//! The paper's Discussion notes PROFET's CNN-trained models "might not
//! show as good result" on Transformer/BERT workloads; these graphs let
//! the `ext_transformer` experiment measure exactly that. The `pixels`
//! workload field is reused as the *sequence length*.
//!
//! Built directly as op lists (attention has no conv-style spatial tape):
//! forward + backward + optimizer, TF op names (BatchMatMulV2, Erf, ...).

use super::{Graph, ModelId};
use crate::ops::{Op, OpClass};

struct Cfg {
    layers: usize,
    d_model: usize,
    heads: usize,
    d_ff: usize,
    vocab: usize,
}

fn emit(ops: &mut Vec<Op>, acts: &mut f64, name: &'static str, layer: String, class: OpClass, flops: f64, bytes: f64, out: Vec<usize>) {
    let op = Op::new(name, layer, class, flops, bytes, out);
    *acts += op.out_elems;
    ops.push(op);
}

fn transformer(model: ModelId, cfg: &Cfg, batch: usize, seq: usize) -> Graph {
    let b = batch as f64;
    let s = seq as f64;
    let d = cfg.d_model as f64;
    let h = cfg.heads as f64;
    let ff = cfg.d_ff as f64;
    let tokens = b * s;

    let mut ops = Vec::new();
    let mut acts = 0.0;
    let mut weights: Vec<f64> = Vec::new();

    // embedding lookup (fwd GatherV2, bwd UnsortedSegmentSum)
    let emb_w = cfg.vocab as f64 * d;
    weights.push(emb_w);
    emit(&mut ops, &mut acts, "GatherV2", "embedding".into(), OpClass::DataMovement, 0.0, 4.0 * (tokens * d), vec![batch, seq, cfg.d_model]);

    let mut bwd: Vec<Op> = Vec::new();
    bwd.push(Op::new("UnsortedSegmentSum", "embedding_grad".to_string(), OpClass::Reduction, tokens * d, 8.0 * tokens * d, vec![cfg.vocab, cfg.d_model]));

    for l in 0..cfg.layers {
        let lname = |part: &str| format!("layer_{l}/{part}");
        // QKV + output projections: 4 dense matmuls (fwd) + 8 (bwd)
        for part in ["q", "k", "v", "attn_out"] {
            let flops = 2.0 * tokens * d * d;
            let bytes = 4.0 * (tokens * d * 2.0 + d * d);
            emit(&mut ops, &mut acts, "MatMul", lname(part), OpClass::MatrixCompute, flops, bytes, vec![batch, seq, cfg.d_model]);
            emit(&mut ops, &mut acts, "BiasAdd", lname(part), OpClass::Elementwise, tokens * d, 8.0 * tokens * d, vec![batch, seq, cfg.d_model]);
            bwd.push(Op::new("MatMul", lname(part), OpClass::MatrixCompute, flops, bytes, vec![cfg.d_model, cfg.d_model]));
            bwd.push(Op::new("MatMul", lname(part), OpClass::MatrixCompute, flops, bytes, vec![batch, seq, cfg.d_model]));
            bwd.push(Op::new("BiasAddGrad", lname(part), OpClass::Reduction, tokens * d, 4.0 * tokens * d, vec![cfg.d_model]));
            weights.push(d * d);
            weights.push(d);
        }
        // attention scores + context: two batched matmuls, softmax between
        let attn_flops = 2.0 * b * s * s * d;
        let attn_bytes = 4.0 * (2.0 * tokens * d + b * h * s * s);
        emit(&mut ops, &mut acts, "BatchMatMulV2", lname("scores"), OpClass::MatrixCompute, attn_flops, attn_bytes, vec![batch, cfg.heads, seq, seq]);
        emit(&mut ops, &mut acts, "Softmax", lname("probs"), OpClass::Reduction, 5.0 * b * h * s * s, 8.0 * b * h * s * s, vec![batch, cfg.heads, seq, seq]);
        emit(&mut ops, &mut acts, "BatchMatMulV2", lname("context"), OpClass::MatrixCompute, attn_flops, attn_bytes, vec![batch, seq, cfg.d_model]);
        for _ in 0..2 {
            bwd.push(Op::new("BatchMatMulV2", lname("attn_grad"), OpClass::MatrixCompute, 2.0 * attn_flops, attn_bytes, vec![batch, cfg.heads, seq, seq]));
        }
        bwd.push(Op::new("Softmax", lname("probs_grad"), OpClass::Reduction, 8.0 * b * h * s * s, 8.0 * b * h * s * s, vec![batch, cfg.heads, seq, seq]));

        // FFN: d -> 4d (GeLU) -> d
        for (part, fin, fout) in [("ffn_up", d, ff), ("ffn_down", ff, d)] {
            let flops = 2.0 * tokens * fin * fout;
            let bytes = 4.0 * (tokens * (fin + fout) + fin * fout);
            emit(&mut ops, &mut acts, "MatMul", lname(part), OpClass::MatrixCompute, flops, bytes, vec![batch, seq, fout as usize]);
            emit(&mut ops, &mut acts, "BiasAdd", lname(part), OpClass::Elementwise, tokens * fout, 8.0 * tokens * fout, vec![batch, seq, fout as usize]);
            bwd.push(Op::new("MatMul", lname(part), OpClass::MatrixCompute, flops, bytes, vec![fin as usize, fout as usize]));
            bwd.push(Op::new("MatMul", lname(part), OpClass::MatrixCompute, flops, bytes, vec![batch, seq, fin as usize]));
            bwd.push(Op::new("BiasAddGrad", lname(part), OpClass::Reduction, tokens * fout, 4.0 * tokens * fout, vec![fout as usize]));
            weights.push(fin * fout);
            weights.push(fout);
        }
        emit(&mut ops, &mut acts, "Erf", lname("gelu"), OpClass::Elementwise, 8.0 * tokens * ff, 8.0 * tokens * ff, vec![batch, seq, cfg.d_ff]);
        bwd.push(Op::new("Erf", lname("gelu_grad"), OpClass::Elementwise, 10.0 * tokens * ff, 8.0 * tokens * ff, vec![batch, seq, cfg.d_ff]));

        // two layer-norms + two residuals
        for part in ["ln_attn", "ln_ffn"] {
            emit(&mut ops, &mut acts, "Mean", lname(part), OpClass::Reduction, tokens * d, 4.0 * tokens * d, vec![batch, seq, 1]);
            emit(&mut ops, &mut acts, "SquaredDifference", lname(part), OpClass::Elementwise, 2.0 * tokens * d, 8.0 * tokens * d, vec![batch, seq, cfg.d_model]);
            emit(&mut ops, &mut acts, "Rsqrt", lname(part), OpClass::Elementwise, tokens, 8.0 * tokens, vec![batch, seq, 1]);
            emit(&mut ops, &mut acts, "Mul", lname(part), OpClass::Elementwise, 2.0 * tokens * d, 12.0 * tokens * d, vec![batch, seq, cfg.d_model]);
            emit(&mut ops, &mut acts, "AddV2", lname(part), OpClass::Elementwise, tokens * d, 12.0 * tokens * d, vec![batch, seq, cfg.d_model]);
            bwd.push(Op::new("RsqrtGrad", lname(part), OpClass::Elementwise, 4.0 * tokens, 8.0 * tokens, vec![batch, seq, 1]));
            bwd.push(Op::new("Mul", lname(part), OpClass::Elementwise, 4.0 * tokens * d, 12.0 * tokens * d, vec![batch, seq, cfg.d_model]));
            bwd.push(Op::new("Sum", lname(part), OpClass::Reduction, 2.0 * tokens * d, 4.0 * tokens * d, vec![cfg.d_model]));
            weights.push(d); // gamma
            weights.push(d); // beta
        }
        for part in ["res_attn", "res_ffn"] {
            emit(&mut ops, &mut acts, "AddV2", lname(part), OpClass::Elementwise, tokens * d, 12.0 * tokens * d, vec![batch, seq, cfg.d_model]);
            bwd.push(Op::new("AddN", lname(part), OpClass::Elementwise, tokens * d, 12.0 * tokens * d, vec![batch, seq, cfg.d_model]));
        }
    }

    // pooled classifier head (Tanh pooler as in BERT) + softmax loss
    let classes = 2usize;
    emit(&mut ops, &mut acts, "MatMul", "pooler".into(), OpClass::MatrixCompute, 2.0 * b * d * d, 4.0 * (b * d * 2.0 + d * d), vec![batch, cfg.d_model]);
    emit(&mut ops, &mut acts, "Tanh", "pooler".into(), OpClass::Elementwise, 4.0 * b * d, 8.0 * b * d, vec![batch, cfg.d_model]);
    emit(&mut ops, &mut acts, "MatMul", "classifier".into(), OpClass::MatrixCompute, 2.0 * b * d * classes as f64, 4.0 * (b * d + d * classes as f64), vec![batch, classes]);
    emit(&mut ops, &mut acts, "Softmax", "classifier".into(), OpClass::Reduction, 5.0 * b * classes as f64, 8.0 * b * classes as f64, vec![batch, classes]);
    bwd.push(Op::new("SoftmaxCrossEntropyWithLogits", "classifier".to_string(), OpClass::Reduction, 8.0 * b * classes as f64, 12.0 * b * classes as f64, vec![batch, classes]));
    bwd.push(Op::new("MatMul", "pooler_grad".to_string(), OpClass::MatrixCompute, 4.0 * b * d * d, 4.0 * (b * d * 2.0 + d * d), vec![batch, cfg.d_model]));
    weights.push(d * d + d);
    weights.push(d * classes as f64 + classes as f64);

    // optimizer (same per-tensor update ops as the CNN tape)
    let mut opt = Vec::new();
    for (i, &w) in weights.iter().enumerate() {
        let layer = format!("training/update_{i}");
        for name in ["Mul", "AssignSubVariableOp", "AssignAddVariableOp"] {
            opt.push(Op::new(name, layer.clone(), OpClass::Optimizer, w, 12.0 * w, vec![w as usize]));
        }
    }

    bwd.reverse();
    ops.extend(bwd);
    ops.extend(opt);
    Graph {
        model,
        batch,
        pixels: seq,
        ops,
        weight_elems: weights.iter().sum(),
        act_elems: acts,
    }
}

/// Small 4-layer encoder (d=256, h=4).
pub fn transformer_small(batch: usize, seq: usize) -> Graph {
    transformer(
        ModelId::TransformerSmall,
        &Cfg {
            layers: 4,
            d_model: 256,
            heads: 4,
            d_ff: 1024,
            vocab: 30_522,
        },
        batch,
        seq,
    )
}

/// BERT-base: 12 layers, d=768, h=12.
pub fn bert_base(batch: usize, seq: usize) -> Graph {
    transformer(
        ModelId::BertBase,
        &Cfg {
            layers: 12,
            d_model: 768,
            heads: 12,
            d_ff: 3072,
            vocab: 30_522,
        },
        batch,
        seq,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    #[test]
    fn bert_base_param_count_ballpark() {
        // published ~110M parameters
        let g = bert_base(8, 128);
        assert!((0.8e8..1.4e8).contains(&g.weight_elems), "{:.3e}", g.weight_elems);
    }

    #[test]
    fn vocabulary_closed() {
        for g in [transformer_small(8, 128), bert_base(4, 64)] {
            for op in &g.ops {
                assert!(ops::in_vocabulary(op.name), "{} not in vocabulary", op.name);
            }
        }
    }

    #[test]
    fn attention_quadratic_in_sequence() {
        let f128 = transformer_small(8, 128).total_flops();
        let f512 = transformer_small(8, 512).total_flops();
        let r = f512 / f128;
        // linear terms give 4x; attention pushes beyond
        assert!(r > 4.5, "seq scaling {r}");
    }

    #[test]
    fn transformer_ops_unseen_in_cnn_corpus() {
        let g = transformer_small(8, 128);
        assert!(g.ops.iter().any(|o| o.name == "BatchMatMulV2"));
        assert!(g.ops.iter().any(|o| o.name == "Erf"));
        // and the CNN zoo never emits them
        let cnn = crate::models::build(crate::models::ModelId::ResNet50, 8, 64).unwrap();
        assert!(!cnn.ops.iter().any(|o| o.name == "BatchMatMulV2" || o.name == "Erf"));
    }
}
