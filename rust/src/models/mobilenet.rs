//! MobileNetV2 (Sandler et al. 2018): inverted residuals, ReLU6, depthwise
//! separable convolutions — the corpus's main source of *unique* operation
//! names (Relu6, Relu6Grad, DepthwiseConv2dNative*) for Fig 13a.

use super::builder::{BuildError, Pad, Tape};
use super::{Graph, ModelId};

/// (expansion t, output channels c, repeats n, first stride s) — the
/// paper's Table 2.
const BLOCKS: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

fn inverted_residual(t: &mut Tape, expand: usize, cout: usize, stride: usize) -> Result<(), BuildError> {
    let cin = t.channels();
    let hidden = cin * expand;
    let use_res = stride == 1 && cin == cout;
    if expand != 1 {
        t.conv(1, hidden, 1, Pad::Same)?;
        t.bn().act();
    }
    t.depthwise(3, stride, Pad::Same)?;
    t.bn().act();
    // linear bottleneck: no activation after projection
    t.conv(1, cout, 1, Pad::Same)?;
    t.bn();
    if use_res {
        t.add_residual();
    }
    Ok(())
}

pub fn mobilenet_v2(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::MobileNetV2, batch, pixels);
    t.use_relu6(true);
    t.conv(3, 32, 2, Pad::Same)?;
    t.bn().act();
    for (expand, cout, reps, stride) in BLOCKS {
        for r in 0..reps {
            let s = if r == 0 { stride } else { 1 };
            inverted_residual(&mut t, expand, cout, s)?;
        }
    }
    t.conv(1, 1280, 1, Pad::Same)?;
    t.bn().act();
    t.gap();
    Ok(t.classifier(1000))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu6_not_relu() {
        let g = mobilenet_v2(8, 96).unwrap();
        assert!(g.ops.iter().any(|o| o.name == "Relu6"));
        assert!(g.ops.iter().any(|o| o.name == "Relu6Grad"));
        assert!(!g.ops.iter().any(|o| o.name == "Relu"));
    }

    #[test]
    fn depthwise_backprops_present() {
        let g = mobilenet_v2(8, 96).unwrap();
        for n in [
            "DepthwiseConv2dNative",
            "DepthwiseConv2dNativeBackpropFilter",
            "DepthwiseConv2dNativeBackpropInput",
        ] {
            assert!(g.ops.iter().any(|o| o.name == n), "{n}");
        }
    }

    #[test]
    fn lightweight_vs_vgg() {
        let mb = mobilenet_v2(16, 224).unwrap().total_flops();
        let vg = super::super::vgg::vgg(ModelId::Vgg16, 16, 224).unwrap().total_flops();
        assert!(mb < vg / 10.0, "mobilenet {mb:.2e} vs vgg {vg:.2e}");
    }
}
