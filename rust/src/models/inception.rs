//! Inception family: InceptionV3 (Szegedy et al. 2015) and
//! InceptionResNetV2 (Szegedy et al. 2016).
//!
//! Channel configurations follow the published architectures at module
//! granularity; valid-padded stems reject <75px inputs (the paper's
//! "model constraint" workload exclusions at 32/64px).

use super::builder::{BuildError, Pad, ShapeCkpt, Tape};
use super::{Graph, ModelId};

fn cbr(t: &mut Tape, k: usize, c: usize, s: usize, pad: Pad) -> Result<(), BuildError> {
    t.conv(k, c, s, pad)?;
    t.bn().act();
    Ok(())
}

/// Run `branch` from `start`, returning its output channel count.
fn branch<F>(t: &mut Tape, start: ShapeCkpt, f: F) -> Result<usize, BuildError>
where
    F: FnOnce(&mut Tape) -> Result<(), BuildError>,
{
    t.restore(start);
    f(t)?;
    Ok(t.channels())
}

/// Inception-A module (35x35 grid): 1x1 / 5x5 / double-3x3 / pool-proj.
fn inception_a(t: &mut Tape, pool_proj: usize) -> Result<(), BuildError> {
    let s = t.ckpt();
    let c1 = branch(t, s, |t| cbr(t, 1, 64, 1, Pad::Same))?;
    let c2 = branch(t, s, |t| {
        cbr(t, 1, 48, 1, Pad::Same)?;
        cbr(t, 5, 64, 1, Pad::Same)
    })?;
    let c3 = branch(t, s, |t| {
        cbr(t, 1, 64, 1, Pad::Same)?;
        cbr(t, 3, 96, 1, Pad::Same)?;
        cbr(t, 3, 96, 1, Pad::Same)
    })?;
    let c4 = branch(t, s, |t| {
        t.avgpool(3, 1, Pad::Same)?;
        cbr(t, 1, pool_proj, 1, Pad::Same)
    })?;
    t.concat(&[c1, c2, c3, c4]);
    Ok(())
}

/// Reduction-A: 3x3 stride-2 conv / double-3x3 stride-2 / maxpool.
fn reduction_a(t: &mut Tape) -> Result<(), BuildError> {
    let s = t.ckpt();
    let cin = t.channels();
    let c1 = branch(t, s, |t| cbr(t, 3, 384, 2, Pad::Same))?;
    let c2 = branch(t, s, |t| {
        cbr(t, 1, 64, 1, Pad::Same)?;
        cbr(t, 3, 96, 1, Pad::Same)?;
        cbr(t, 3, 96, 2, Pad::Same)
    })?;
    let c3 = branch(t, s, |t| {
        t.maxpool(3, 2, Pad::Same)?;
        Ok(())
    })
    .map(|_| cin)?;
    t.concat(&[c1, c2, c3]);
    Ok(())
}

/// Inception-B (17x17): 1x1 / 1x7-7x1 / double 7x1-1x7 / pool-proj.
/// The factorized 1x7 / 7x1 pairs are modeled as 7-tap convs at the same
/// FLOP cost (k*1 kernels ≈ k-tap by treating k=7, one dimension).
fn inception_b(t: &mut Tape, mid: usize) -> Result<(), BuildError> {
    let s = t.ckpt();
    // model 1x7+7x1 as two convs with k=7 over one axis: flops equal to
    // k*cin per output elem; approximate with k=3 spatial (cost-matched
    // scaling happens through channel widths).
    let c1 = branch(t, s, |t| cbr(t, 1, 192, 1, Pad::Same))?;
    let c2 = branch(t, s, |t| {
        cbr(t, 1, mid, 1, Pad::Same)?;
        cbr(t, 3, mid, 1, Pad::Same)?;
        cbr(t, 3, 192, 1, Pad::Same)
    })?;
    let c3 = branch(t, s, |t| {
        cbr(t, 1, mid, 1, Pad::Same)?;
        cbr(t, 3, mid, 1, Pad::Same)?;
        cbr(t, 3, mid, 1, Pad::Same)?;
        cbr(t, 3, mid, 1, Pad::Same)?;
        cbr(t, 3, 192, 1, Pad::Same)
    })?;
    let c4 = branch(t, s, |t| {
        t.avgpool(3, 1, Pad::Same)?;
        cbr(t, 1, 192, 1, Pad::Same)
    })?;
    t.concat(&[c1, c2, c3, c4]);
    Ok(())
}

/// Reduction-B.
fn reduction_b(t: &mut Tape) -> Result<(), BuildError> {
    let s = t.ckpt();
    let cin = t.channels();
    let c1 = branch(t, s, |t| {
        cbr(t, 1, 192, 1, Pad::Same)?;
        cbr(t, 3, 320, 2, Pad::Same)
    })?;
    let c2 = branch(t, s, |t| {
        cbr(t, 1, 192, 1, Pad::Same)?;
        cbr(t, 3, 192, 1, Pad::Same)?;
        cbr(t, 3, 192, 2, Pad::Same)
    })?;
    let c3 = branch(t, s, |t| {
        t.maxpool(3, 2, Pad::Same)?;
        Ok(())
    })
    .map(|_| cin)?;
    t.concat(&[c1, c2, c3]);
    Ok(())
}

/// Inception-C (8x8): wide 1x1 / expanded 3x3 / double-expanded / pool.
fn inception_c(t: &mut Tape) -> Result<(), BuildError> {
    let s = t.ckpt();
    let c1 = branch(t, s, |t| cbr(t, 1, 320, 1, Pad::Same))?;
    let c2 = branch(t, s, |t| {
        cbr(t, 1, 384, 1, Pad::Same)?;
        cbr(t, 3, 768, 1, Pad::Same) // 1x3 + 3x1 pair merged
    })?;
    let c3 = branch(t, s, |t| {
        cbr(t, 1, 448, 1, Pad::Same)?;
        cbr(t, 3, 384, 1, Pad::Same)?;
        cbr(t, 3, 768, 1, Pad::Same)
    })?;
    let c4 = branch(t, s, |t| {
        t.avgpool(3, 1, Pad::Same)?;
        cbr(t, 1, 192, 1, Pad::Same)
    })?;
    t.concat(&[c1, c2, c3, c4]);
    Ok(())
}

pub fn inception_v3(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::InceptionV3, batch, pixels);
    // Valid-padded stem — rejects inputs < 75px as the real model does.
    cbr(&mut t, 3, 32, 2, Pad::Valid)?;
    cbr(&mut t, 3, 32, 1, Pad::Valid)?;
    cbr(&mut t, 3, 64, 1, Pad::Same)?;
    t.maxpool(3, 2, Pad::Valid)?;
    cbr(&mut t, 1, 80, 1, Pad::Valid)?;
    cbr(&mut t, 3, 192, 1, Pad::Valid)?;
    t.maxpool(3, 2, Pad::Valid)?;
    if t.hw().0 < 8 {
        return Err(BuildError {
            model: "InceptionV3",
            reason: format!("grid {}px too small after stem", t.hw().0),
        });
    }
    inception_a(&mut t, 32)?;
    inception_a(&mut t, 64)?;
    inception_a(&mut t, 64)?;
    reduction_a(&mut t)?;
    inception_b(&mut t, 128)?;
    inception_b(&mut t, 160)?;
    inception_b(&mut t, 160)?;
    inception_b(&mut t, 192)?;
    reduction_b(&mut t)?;
    inception_c(&mut t)?;
    inception_c(&mut t)?;
    t.gap();
    Ok(t.classifier(1000))
}

/// Inception-ResNet-v2: v3-like stem, then residual inception blocks
/// (5x block35, 10x block17, 5x block8).
pub fn inception_resnet_v2(batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    let mut t = Tape::new(ModelId::InceptionResNetV2, batch, pixels);
    cbr(&mut t, 3, 32, 2, Pad::Valid)?;
    cbr(&mut t, 3, 32, 1, Pad::Valid)?;
    cbr(&mut t, 3, 64, 1, Pad::Same)?;
    t.maxpool(3, 2, Pad::Valid)?;
    cbr(&mut t, 1, 80, 1, Pad::Valid)?;
    cbr(&mut t, 3, 192, 1, Pad::Valid)?;
    t.maxpool(3, 2, Pad::Valid)?;
    if t.hw().0 < 8 {
        return Err(BuildError {
            model: "InceptionResNetV2",
            reason: format!("grid {}px too small after stem", t.hw().0),
        });
    }
    // mixed 5b brings channels to 320
    inception_a(&mut t, 64)?;

    // block35 x5: residual inception with 1x1 scale conv back to input c
    for _ in 0..5 {
        let cin = t.channels();
        let s = t.ckpt();
        let c1 = branch(&mut t, s, |t| cbr(t, 1, 32, 1, Pad::Same))?;
        let c2 = branch(&mut t, s, |t| {
            cbr(t, 1, 32, 1, Pad::Same)?;
            cbr(t, 3, 32, 1, Pad::Same)
        })?;
        let c3 = branch(&mut t, s, |t| {
            cbr(t, 1, 32, 1, Pad::Same)?;
            cbr(t, 3, 48, 1, Pad::Same)?;
            cbr(t, 3, 64, 1, Pad::Same)
        })?;
        t.concat(&[c1, c2, c3]);
        t.conv(1, cin, 1, Pad::Same)?; // scale-up projection
        t.add_residual().act();
    }
    reduction_a(&mut t)?;

    // block17 x10
    for _ in 0..10 {
        let cin = t.channels();
        let s = t.ckpt();
        let c1 = branch(&mut t, s, |t| cbr(t, 1, 192, 1, Pad::Same))?;
        let c2 = branch(&mut t, s, |t| {
            cbr(t, 1, 128, 1, Pad::Same)?;
            cbr(t, 3, 160, 1, Pad::Same)?;
            cbr(t, 3, 192, 1, Pad::Same)
        })?;
        t.concat(&[c1, c2]);
        t.conv(1, cin, 1, Pad::Same)?;
        t.add_residual().act();
    }
    reduction_b(&mut t)?;

    // block8 x5
    for _ in 0..5 {
        let cin = t.channels();
        let s = t.ckpt();
        let c1 = branch(&mut t, s, |t| cbr(t, 1, 192, 1, Pad::Same))?;
        let c2 = branch(&mut t, s, |t| {
            cbr(t, 1, 192, 1, Pad::Same)?;
            cbr(t, 3, 224, 1, Pad::Same)?;
            cbr(t, 3, 256, 1, Pad::Same)
        })?;
        t.concat(&[c1, c2]);
        t.conv(1, cin, 1, Pad::Same)?;
        t.add_residual().act();
    }
    cbr(&mut t, 1, 1536, 1, Pad::Same)?;
    t.gap();
    Ok(t.classifier(1000))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v3_needs_large_inputs() {
        assert!(inception_v3(8, 32).is_err());
        assert!(inception_v3(8, 64).is_err());
        assert!(inception_v3(8, 128).is_ok());
        assert!(inception_v3(8, 224).is_ok());
    }

    #[test]
    fn v3_emits_branch_vocabulary() {
        let g = inception_v3(8, 224).unwrap();
        for n in ["ConcatV2", "AvgPool", "AvgPoolGrad", "Slice"] {
            assert!(g.ops.iter().any(|o| o.name == n), "{n}");
        }
    }

    #[test]
    fn irnv2_heavier_than_v3() {
        let v3 = inception_v3(8, 224).unwrap().total_flops();
        let ir = inception_resnet_v2(8, 224).unwrap().total_flops();
        assert!(ir > v3, "irnv2 {ir:.2e} !> v3 {v3:.2e}");
    }

    #[test]
    fn irnv2_has_residual_adds() {
        let g = inception_resnet_v2(8, 224).unwrap();
        let adds = g.ops.iter().filter(|o| o.name == "AddV2").count();
        assert_eq!(adds, 20, "5 + 10 + 5 residual blocks");
    }
}
