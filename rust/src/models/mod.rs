//! CNN model zoo: the paper's 15 architectures as op graphs.
//!
//! Each architecture is expressed with the [`builder::Tape`] DSL, which
//! expands layers into forward + backward + optimizer [`crate::ops::Op`]s
//! with exact shapes, FLOPs, and byte counts. Graphs are what the
//! simulator executes and the profiler emulator records.

pub mod builder;
mod classic;
mod inception;
mod mobilenet;
mod resnet;
mod transformer;
mod vgg;

pub use builder::{BuildError, Pad, Tape};

use crate::ops::Op;
use std::fmt;

/// The paper's model set M (Sec III).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelId {
    AlexNet,
    LeNet5,
    InceptionV3,
    InceptionResNetV2,
    MobileNetV2,
    MnistCnn,
    Cifar10Cnn,
    ResNetSmall,
    ResNet18,
    ResNet34,
    ResNet50,
    Vgg11,
    Vgg13,
    Vgg16,
    Vgg19,
    /// Sec VII extension (non-CNN): 4-layer encoder, d=256. `pixels` is
    /// reused as the sequence length. NOT part of the paper corpus
    /// ([`ModelId::ALL`]).
    TransformerSmall,
    /// Sec VII extension: BERT-base (12 layers, d=768).
    BertBase,
}

impl ModelId {
    pub const ALL: [ModelId; 15] = [
        ModelId::AlexNet,
        ModelId::LeNet5,
        ModelId::InceptionV3,
        ModelId::InceptionResNetV2,
        ModelId::MobileNetV2,
        ModelId::MnistCnn,
        ModelId::Cifar10Cnn,
        ModelId::ResNetSmall,
        ModelId::ResNet18,
        ModelId::ResNet34,
        ModelId::ResNet50,
        ModelId::Vgg11,
        ModelId::Vgg13,
        ModelId::Vgg16,
        ModelId::Vgg19,
    ];

    /// Sec VII extension models (excluded from the paper corpus).
    pub const EXTENDED: [ModelId; 2] = [ModelId::TransformerSmall, ModelId::BertBase];

    /// Models whose op vocabulary contains operations rarely used by the
    /// rest of the corpus (Fig 13a: Relu6/DepthwiseConv2d in MobileNetV2,
    /// AvgPool/ConcatV2/Pad mixes in the Inception family, the large-LRN-
    /// era AlexNet). Used by the clustering ablation.
    pub fn has_unique_ops(self) -> bool {
        matches!(
            self,
            ModelId::MobileNetV2 | ModelId::InceptionV3 | ModelId::InceptionResNetV2
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            ModelId::AlexNet => "AlexNet",
            ModelId::LeNet5 => "LeNet5",
            ModelId::InceptionV3 => "InceptionV3",
            ModelId::InceptionResNetV2 => "InceptionResNetV2",
            ModelId::MobileNetV2 => "MobileNetV2",
            ModelId::MnistCnn => "MNIST_CNN",
            ModelId::Cifar10Cnn => "CIFAR10_CNN",
            ModelId::ResNetSmall => "ResNetSmall",
            ModelId::ResNet18 => "ResNet18",
            ModelId::ResNet34 => "ResNet34",
            ModelId::ResNet50 => "ResNet50",
            ModelId::Vgg11 => "VGG11",
            ModelId::Vgg13 => "VGG13",
            ModelId::Vgg16 => "VGG16",
            ModelId::Vgg19 => "VGG19",
            ModelId::TransformerSmall => "TransformerSmall",
            ModelId::BertBase => "BertBase",
        }
    }

    pub fn from_name(name: &str) -> Option<ModelId> {
        ModelId::ALL
            .into_iter()
            .chain(ModelId::EXTENDED)
            .find(|m| m.name() == name)
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A fully expanded training-step op graph for (model, batch, pixels).
#[derive(Debug, Clone)]
pub struct Graph {
    pub model: ModelId,
    pub batch: usize,
    /// Input image side length (images are pixels x pixels x 3).
    pub pixels: usize,
    /// Forward + backward + optimizer ops, in emission order.
    pub ops: Vec<Op>,
    /// Trainable parameter elements.
    pub weight_elems: f64,
    /// Stored forward activations (elements) — retained for backprop.
    pub act_elems: f64,
}

impl Graph {
    /// Approximate device-memory footprint in bytes for the training step:
    /// weights + grads + 2 Adam moments, stored activations (x2 for
    /// workspace), and the input batch.
    pub fn memory_bytes(&self) -> f64 {
        let weights = self.weight_elems * 4.0 * 4.0;
        let acts = self.act_elems * 4.0 * 2.0;
        let input = (self.batch * self.pixels * self.pixels * 3) as f64 * 4.0;
        let framework = 1.2e9; // CUDA context + cuDNN workspace floor
        weights + acts + input + framework
    }

    /// Total FLOPs of the training step.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    /// Total bytes moved.
    pub fn total_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.bytes).sum()
    }
}

/// Build the training-step graph for a model at (batch, pixels).
///
/// Returns `Err(BuildError)` when the architecture cannot accept the input
/// size (e.g. InceptionV3's valid-padded stem collapses below 1x1 on 32px
/// inputs) — these are the paper's "model constraint" exclusions.
pub fn build(model: ModelId, batch: usize, pixels: usize) -> Result<Graph, BuildError> {
    match model {
        ModelId::AlexNet => classic::alexnet(batch, pixels),
        ModelId::LeNet5 => classic::lenet5(batch, pixels),
        ModelId::MnistCnn => classic::mnist_cnn(batch, pixels),
        ModelId::Cifar10Cnn => classic::cifar10_cnn(batch, pixels),
        ModelId::InceptionV3 => inception::inception_v3(batch, pixels),
        ModelId::InceptionResNetV2 => inception::inception_resnet_v2(batch, pixels),
        ModelId::MobileNetV2 => mobilenet::mobilenet_v2(batch, pixels),
        ModelId::ResNetSmall => resnet::resnet_small(batch, pixels),
        ModelId::ResNet18 => resnet::resnet18(batch, pixels),
        ModelId::ResNet34 => resnet::resnet34(batch, pixels),
        ModelId::ResNet50 => resnet::resnet50(batch, pixels),
        ModelId::Vgg11 => vgg::vgg(ModelId::Vgg11, batch, pixels),
        ModelId::Vgg13 => vgg::vgg(ModelId::Vgg13, batch, pixels),
        ModelId::Vgg16 => vgg::vgg(ModelId::Vgg16, batch, pixels),
        ModelId::Vgg19 => vgg::vgg(ModelId::Vgg19, batch, pixels),
        ModelId::TransformerSmall => Ok(transformer::transformer_small(batch, pixels)),
        ModelId::BertBase => Ok(transformer::bert_base(batch, pixels)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    #[test]
    fn all_models_build_at_224() {
        for m in ModelId::ALL {
            let g = build(m, 16, 224).unwrap_or_else(|e| panic!("{m}: {e}"));
            assert!(!g.ops.is_empty(), "{m}");
            assert!(g.weight_elems > 1e3, "{m} weights {}", g.weight_elems);
            assert!(g.total_flops() > 1e6, "{m}");
        }
    }

    #[test]
    fn vocabulary_closed(){
        for m in ModelId::ALL {
            if let Ok(g) = build(m, 16, 128) {
                for op in &g.ops {
                    assert!(ops::in_vocabulary(op.name), "{m}: {} not in vocabulary", op.name);
                }
            }
        }
    }

    #[test]
    fn inception_rejects_tiny_inputs() {
        assert!(build(ModelId::InceptionV3, 16, 32).is_err());
        assert!(build(ModelId::InceptionResNetV2, 16, 32).is_err());
        assert!(build(ModelId::InceptionV3, 16, 224).is_ok());
    }

    #[test]
    fn flops_scale_with_batch() {
        let g16 = build(ModelId::Vgg16, 16, 128).unwrap();
        let g64 = build(ModelId::Vgg16, 64, 128).unwrap();
        let r = g64.total_flops() / g16.total_flops();
        assert!(r > 3.5 && r < 4.2, "flops ratio {r}");
        // weights do not scale with batch
        assert_eq!(g16.weight_elems, g64.weight_elems);
    }

    #[test]
    fn flops_scale_with_pixels() {
        let a = build(ModelId::ResNet50, 16, 64).unwrap();
        let b = build(ModelId::ResNet50, 16, 128).unwrap();
        let r = b.total_flops() / a.total_flops();
        assert!(r > 3.0 && r < 5.0, "pixel flops ratio {r}");
    }

    #[test]
    fn known_parameter_counts_ballpark() {
        // Published param counts (within modeling tolerance):
        // VGG16 ~138M @224, ResNet50 ~25.6M, MobileNetV2 ~3.5M, AlexNet ~61M.
        let vgg = build(ModelId::Vgg16, 1, 224).unwrap().weight_elems;
        assert!((1.1e8..1.6e8).contains(&vgg), "vgg16 params {vgg:.3e}");
        let r50 = build(ModelId::ResNet50, 1, 224).unwrap().weight_elems;
        assert!((2.0e7..3.2e7).contains(&r50), "resnet50 params {r50:.3e}");
        let mb = build(ModelId::MobileNetV2, 1, 224).unwrap().weight_elems;
        assert!((2.0e6..6.0e6).contains(&mb), "mobilenetv2 params {mb:.3e}");
        let alex = build(ModelId::AlexNet, 1, 224).unwrap().weight_elems;
        assert!((4.5e7..8.0e7).contains(&alex), "alexnet params {alex:.3e}");
        let lenet = build(ModelId::LeNet5, 1, 32).unwrap().weight_elems;
        assert!((4.0e4..1.0e5).contains(&lenet), "lenet params {lenet:.3e}");
    }

    #[test]
    fn resnet50_flops_ballpark() {
        // Published: ~4 GFLOPs fwd inference @224 → training step with
        // backward ≈ 3x fwd ≈ 12 GFLOPs per image.
        let g = build(ModelId::ResNet50, 1, 224).unwrap();
        let gf = g.total_flops() / 1e9;
        assert!((7.0..25.0).contains(&gf), "resnet50 train GFLOPs {gf}");
    }

    #[test]
    fn unique_op_models_emit_unique_ops() {
        let g = build(ModelId::MobileNetV2, 16, 128).unwrap();
        assert!(g.ops.iter().any(|o| o.name == "Relu6"));
        assert!(g.ops.iter().any(|o| o.name == "DepthwiseConv2dNative"));
        let g = build(ModelId::InceptionV3, 16, 224).unwrap();
        assert!(g.ops.iter().any(|o| o.name == "ConcatV2"));
        assert!(g.ops.iter().any(|o| o.name == "AvgPool"));
        // VGG uses neither
        let g = build(ModelId::Vgg16, 16, 128).unwrap();
        assert!(!g.ops.iter().any(|o| o.name == "Relu6"));
    }

    #[test]
    fn backward_ops_present_for_training() {
        for m in [ModelId::Vgg11, ModelId::ResNet18, ModelId::MobileNetV2] {
            let g = build(m, 16, 128).unwrap();
            assert!(g.ops.iter().any(|o| o.name.contains("Backprop") || o.name.ends_with("Grad")), "{m}");
            assert!(g.ops.iter().any(|o| o.name == "AssignSubVariableOp"), "{m}");
        }
    }

    #[test]
    fn names_roundtrip() {
        for m in ModelId::ALL {
            assert_eq!(ModelId::from_name(m.name()), Some(m));
        }
    }
}
