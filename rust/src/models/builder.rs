//! Tape-style graph builder: layer calls emit forward, backward, and
//! optimizer ops with exact shapes and roofline accounting.
//!
//! The builder tracks the activation shape (H, W, C) through the network,
//! mirrors TF/Keras layer naming (`conv2d_3`, `dense_1`, ...) for the
//! profiler's operation-details field, and auto-generates the backward op
//! for every forward op so a finished tape is a complete *training step*.

use crate::models::{Graph, ModelId};
use crate::ops::{Op, OpClass};
use std::collections::HashMap;
use std::fmt;

/// Padding mode for convolutions/pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pad {
    /// TF 'SAME': out = ceil(in / stride).
    Same,
    /// TF 'VALID': out = (in - k)/stride + 1; fails if in < k.
    Valid,
}

/// Architecture cannot accept the requested input size (paper's "model
/// constraint" workload exclusions).
#[derive(Debug, Clone)]
pub struct BuildError {
    pub model: &'static str,
    pub reason: String,
}

impl fmt::Display for BuildError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.model, self.reason)
    }
}

impl std::error::Error for BuildError {}

/// Saved activation-shape checkpoint for branching (Inception) and
/// residual (ResNet) topologies.
#[derive(Debug, Clone, Copy)]
pub struct ShapeCkpt {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

/// The tape.
pub struct Tape {
    model: ModelId,
    batch: usize,
    pixels: usize,
    h: usize,
    w: usize,
    c: usize,
    fwd: Vec<Op>,
    bwd: Vec<Op>,
    opt: Vec<Op>,
    weight_tensors: Vec<f64>,
    act_elems: f64,
    counters: HashMap<&'static str, usize>,
    emitted_first_conv: bool,
    /// Elementwise activation emitted by `act()`: Relu or Relu6.
    relu6: bool,
}

impl Tape {
    pub fn new(model: ModelId, batch: usize, pixels: usize) -> Self {
        let mut t = Self {
            model,
            batch,
            pixels,
            h: pixels,
            w: pixels,
            c: 3,
            fwd: Vec::new(),
            bwd: Vec::new(),
            opt: Vec::new(),
            weight_tensors: Vec::new(),
            act_elems: 0.0,
            counters: HashMap::new(),
            emitted_first_conv: false,
            relu6: false,
        };
        // Input pipeline: uint8 decode -> float cast on device.
        let elems = t.elems();
        t.push_fwd(Op::new(
            "Cast",
            t.layer_name("cast"),
            OpClass::Elementwise,
            elems,
            5.0 * elems,
            t.shape_vec(),
        ));
        t
    }

    /// Use Relu6 for subsequent `act()` calls (MobileNetV2).
    pub fn use_relu6(&mut self, yes: bool) {
        self.relu6 = yes;
    }

    fn err(&self, reason: impl Into<String>) -> BuildError {
        BuildError {
            model: self.model.name(),
            reason: reason.into(),
        }
    }

    fn layer_name(&self, base: &'static str) -> String {
        // Note: counter is advanced by `bump`, this only formats.
        format!("{base}_{}", self.counters.get(base).copied().unwrap_or(0))
    }

    fn bump(&mut self, base: &'static str) -> String {
        let ctr = self.counters.entry(base).or_insert(0);
        let name = format!("{base}_{ctr}");
        *ctr += 1;
        name
    }

    fn elems(&self) -> f64 {
        (self.batch * self.h * self.w * self.c) as f64
    }

    fn shape_vec(&self) -> Vec<usize> {
        vec![self.batch, self.h, self.w, self.c]
    }

    /// Current spatial/channel shape (for branch bookkeeping).
    pub fn ckpt(&self) -> ShapeCkpt {
        ShapeCkpt {
            h: self.h,
            w: self.w,
            c: self.c,
        }
    }

    /// Restore a shape checkpoint (start of a parallel branch).
    pub fn restore(&mut self, s: ShapeCkpt) {
        self.h = s.h;
        self.w = s.w;
        self.c = s.c;
    }

    pub fn channels(&self) -> usize {
        self.c
    }

    pub fn hw(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    fn push_fwd(&mut self, op: Op) {
        self.act_elems += op.out_elems;
        self.fwd.push(op);
    }

    fn out_dim(&self, n: usize, k: usize, s: usize, pad: Pad) -> Result<usize, BuildError> {
        match pad {
            Pad::Same => Ok(n.div_ceil(s)),
            Pad::Valid => {
                if n < k {
                    Err(self.err(format!("spatial {n} < kernel {k} (valid padding)")))
                } else {
                    Ok((n - k) / s + 1)
                }
            }
        }
    }

    /// 2D convolution (+ bias) with auto backward.
    pub fn conv(
        &mut self,
        k: usize,
        cout: usize,
        stride: usize,
        pad: Pad,
    ) -> Result<&mut Self, BuildError> {
        let cin = self.c;
        let oh = self.out_dim(self.h, k, stride, pad)?;
        let ow = self.out_dim(self.w, k, stride, pad)?;
        let layer = self.bump("conv2d");
        let in_elems = self.elems();
        let out_elems = (self.batch * oh * ow * cout) as f64;
        let w_elems = (k * k * cin * cout) as f64;
        let flops = 2.0 * out_elems * (k * k * cin) as f64;
        let bytes = 4.0 * (in_elems + w_elems + out_elems);

        self.push_fwd(Op::new(
            "Conv2D",
            layer.clone(),
            OpClass::MatrixCompute,
            flops,
            bytes,
            vec![self.batch, oh, ow, cout],
        ));
        // dL/dW — always computed.
        self.bwd.push(Op::new(
            "Conv2DBackpropFilter",
            layer.clone(),
            OpClass::MatrixCompute,
            flops,
            bytes,
            vec![k, k, cin, cout],
        ));
        // dL/dX — skipped for the very first conv (input needs no grad).
        if self.emitted_first_conv {
            self.bwd.push(Op::new(
                "Conv2DBackpropInput",
                layer.clone(),
                OpClass::MatrixCompute,
                flops,
                bytes,
                vec![self.batch, self.h, self.w, cin],
            ));
        }
        self.emitted_first_conv = true;
        self.h = oh;
        self.w = ow;
        self.c = cout;
        self.bias(layer, out_elems, cout);
        self.weight_tensors.push(w_elems);
        Ok(self)
    }

    /// Depthwise 3x3-style convolution (MobileNet).
    pub fn depthwise(&mut self, k: usize, stride: usize, pad: Pad) -> Result<&mut Self, BuildError> {
        let c = self.c;
        let oh = self.out_dim(self.h, k, stride, pad)?;
        let ow = self.out_dim(self.w, k, stride, pad)?;
        let layer = self.bump("depthwise_conv2d");
        let in_elems = self.elems();
        let out_elems = (self.batch * oh * ow * c) as f64;
        let w_elems = (k * k * c) as f64;
        let flops = 2.0 * out_elems * (k * k) as f64;
        let bytes = 4.0 * (in_elems + w_elems + out_elems);
        self.push_fwd(Op::new(
            "DepthwiseConv2dNative",
            layer.clone(),
            OpClass::Depthwise,
            flops,
            bytes,
            vec![self.batch, oh, ow, c],
        ));
        self.bwd.push(Op::new(
            "DepthwiseConv2dNativeBackpropFilter",
            layer.clone(),
            OpClass::Depthwise,
            flops,
            bytes,
            vec![k, k, c, 1],
        ));
        self.bwd.push(Op::new(
            "DepthwiseConv2dNativeBackpropInput",
            layer,
            OpClass::Depthwise,
            flops,
            bytes,
            vec![self.batch, self.h, self.w, c],
        ));
        self.h = oh;
        self.w = ow;
        self.weight_tensors.push(w_elems);
        Ok(self)
    }

    fn bias(&mut self, layer: String, out_elems: f64, cout: usize) {
        self.push_fwd(Op::new(
            "BiasAdd",
            layer.clone(),
            OpClass::Elementwise,
            out_elems,
            2.0 * 4.0 * out_elems,
            self.shape_vec(),
        ));
        self.bwd.push(Op::new(
            "BiasAddGrad",
            layer,
            OpClass::Reduction,
            out_elems,
            4.0 * out_elems,
            vec![cout],
        ));
        self.weight_tensors.push(cout as f64);
    }

    /// Fused batch normalization (+ backward + rsqrt grad).
    pub fn bn(&mut self) -> &mut Self {
        let layer = self.bump("batch_normalization");
        let elems = self.elems();
        self.push_fwd(Op::new(
            "FusedBatchNormV3",
            layer.clone(),
            OpClass::Normalization,
            10.0 * elems,
            3.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self.bwd.push(Op::new(
            "FusedBatchNormGradV3",
            layer.clone(),
            OpClass::Normalization,
            15.0 * elems,
            4.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self.bwd.push(Op::new(
            "RsqrtGrad",
            layer,
            OpClass::Elementwise,
            4.0 * self.c as f64,
            4.0 * 2.0 * self.c as f64,
            vec![self.c],
        ));
        // gamma/beta
        self.weight_tensors.push(self.c as f64);
        self.weight_tensors.push(self.c as f64);
        self
    }

    /// ReLU (or ReLU6 when `use_relu6` was set).
    pub fn act(&mut self) -> &mut Self {
        let (fname, bname) = if self.relu6 {
            ("Relu6", "Relu6Grad")
        } else {
            ("Relu", "ReluGrad")
        };
        let layer = self.bump("activation");
        let elems = self.elems();
        self.push_fwd(Op::new(
            fname,
            layer.clone(),
            OpClass::Elementwise,
            elems,
            2.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self.bwd.push(Op::new(
            bname,
            layer,
            OpClass::Elementwise,
            elems,
            3.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self
    }

    fn pool(
        &mut self,
        fname: &'static str,
        bname: &'static str,
        k: usize,
        stride: usize,
        pad: Pad,
    ) -> Result<&mut Self, BuildError> {
        let oh = self.out_dim(self.h, k, stride, pad)?;
        let ow = self.out_dim(self.w, k, stride, pad)?;
        let layer = self.bump(if fname == "MaxPool" {
            "max_pooling2d"
        } else {
            "average_pooling2d"
        });
        let in_elems = self.elems();
        let out_elems = (self.batch * oh * ow * self.c) as f64;
        self.push_fwd(Op::new(
            fname,
            layer.clone(),
            OpClass::Pooling,
            out_elems * (k * k) as f64,
            4.0 * (in_elems + out_elems),
            vec![self.batch, oh, ow, self.c],
        ));
        self.bwd.push(Op::new(
            bname,
            layer,
            OpClass::Pooling,
            in_elems,
            4.0 * (in_elems + 2.0 * out_elems),
            vec![self.batch, self.h, self.w, self.c],
        ));
        self.h = oh;
        self.w = ow;
        Ok(self)
    }

    pub fn maxpool(&mut self, k: usize, stride: usize, pad: Pad) -> Result<&mut Self, BuildError> {
        self.pool("MaxPool", "MaxPoolGrad", k, stride, pad)
    }

    pub fn avgpool(&mut self, k: usize, stride: usize, pad: Pad) -> Result<&mut Self, BuildError> {
        self.pool("AvgPool", "AvgPoolGrad", k, stride, pad)
    }

    /// Global average pooling → [B, 1, 1, C] (Mean fwd, Tile bwd).
    pub fn gap(&mut self) -> &mut Self {
        let layer = self.bump("global_average_pooling2d");
        let in_elems = self.elems();
        self.push_fwd(Op::new(
            "Mean",
            layer.clone(),
            OpClass::Reduction,
            in_elems,
            4.0 * (in_elems + (self.batch * self.c) as f64),
            vec![self.batch, 1, 1, self.c],
        ));
        self.bwd.push(Op::new(
            "Tile",
            layer,
            OpClass::DataMovement,
            0.0,
            4.0 * in_elems,
            self.shape_vec(),
        ));
        self.h = 1;
        self.w = 1;
        self
    }

    /// Dense (fully connected) layer on the flattened activation.
    pub fn dense(&mut self, n: usize) -> &mut Self {
        let fan_in = self.h * self.w * self.c;
        if self.h != 1 || self.w != 1 {
            // implicit flatten
            let layer = self.bump("flatten");
            self.push_fwd(Op::new(
                "Reshape",
                layer,
                OpClass::DataMovement,
                0.0,
                0.0,
                vec![self.batch, fan_in],
            ));
            self.h = 1;
            self.w = 1;
        }
        let layer = self.bump("dense");
        let out_elems = (self.batch * n) as f64;
        let w_elems = (fan_in * n) as f64;
        let flops = 2.0 * self.batch as f64 * w_elems;
        let bytes = 4.0 * ((self.batch * fan_in) as f64 + w_elems + out_elems);
        self.push_fwd(Op::new(
            "MatMul",
            layer.clone(),
            OpClass::MatrixCompute,
            flops,
            bytes,
            vec![self.batch, n],
        ));
        // dW = X^T G and dX = G W^T — two more MatMuls.
        self.bwd.push(Op::new(
            "MatMul",
            layer.clone(),
            OpClass::MatrixCompute,
            flops,
            bytes,
            vec![fan_in, n],
        ));
        self.bwd.push(Op::new(
            "MatMul",
            layer.clone(),
            OpClass::MatrixCompute,
            flops,
            bytes,
            vec![self.batch, fan_in],
        ));
        self.c = n;
        self.bias(layer, out_elems, n);
        self.weight_tensors.push(w_elems);
        self
    }

    /// Residual add with the tensor saved at `ckpt` (shapes must match).
    pub fn add_residual(&mut self) -> &mut Self {
        let layer = self.bump("add");
        let elems = self.elems();
        self.push_fwd(Op::new(
            "AddV2",
            layer.clone(),
            OpClass::Elementwise,
            elems,
            3.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self.bwd.push(Op::new(
            "AddN",
            layer,
            OpClass::Elementwise,
            elems,
            3.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self
    }

    /// Channel concat of branch outputs with channel counts `parts`
    /// (current spatial dims). Sets C = sum(parts).
    pub fn concat(&mut self, parts: &[usize]) -> &mut Self {
        let layer = self.bump("concatenate");
        let c: usize = parts.iter().sum();
        self.c = c;
        let elems = self.elems();
        self.push_fwd(Op::new(
            "ConcatV2",
            layer.clone(),
            OpClass::DataMovement,
            0.0,
            2.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        // backward: one slice per branch
        for (i, p) in parts.iter().enumerate() {
            let part_elems = (self.batch * self.h * self.w * p) as f64;
            self.bwd.push(Op::new(
                "Slice",
                format!("{layer}_grad{i}"),
                OpClass::DataMovement,
                0.0,
                2.0 * 4.0 * part_elems,
                vec![self.batch, self.h, self.w, *p],
            ));
        }
        self
    }

    /// Spatial zero-padding (Inception stems / explicit pads).
    pub fn pad2d(&mut self, p: usize) -> &mut Self {
        let layer = self.bump("zero_padding2d");
        self.h += 2 * p;
        self.w += 2 * p;
        let elems = self.elems();
        self.push_fwd(Op::new(
            "Pad",
            layer.clone(),
            OpClass::DataMovement,
            0.0,
            2.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self.bwd.push(Op::new(
            "Slice",
            layer,
            OpClass::DataMovement,
            0.0,
            2.0 * 4.0 * elems,
            self.shape_vec(),
        ));
        self
    }

    /// Classifier head: dense(classes) + softmax + cross-entropy loss (+
    /// metric argmax), then finishes the tape with optimizer updates.
    pub fn classifier(mut self, classes: usize) -> Graph {
        self.dense(classes);
        let layer = self.bump("predictions");
        let logits = (self.batch * classes) as f64;
        self.push_fwd(Op::new(
            "Softmax",
            layer.clone(),
            OpClass::Reduction,
            5.0 * logits,
            2.0 * 4.0 * logits,
            vec![self.batch, classes],
        ));
        self.push_fwd(Op::new(
            "ArgMax",
            layer.clone(),
            OpClass::Reduction,
            logits,
            4.0 * logits,
            vec![self.batch],
        ));
        self.bwd.push(Op::new(
            "SoftmaxCrossEntropyWithLogits",
            layer.clone(),
            OpClass::Reduction,
            8.0 * logits,
            3.0 * 4.0 * logits,
            vec![self.batch, classes],
        ));
        self.bwd.push(Op::new(
            "Sub",
            layer,
            OpClass::Elementwise,
            logits,
            3.0 * 4.0 * logits,
            vec![self.batch, classes],
        ));
        self.finish()
    }

    /// Emit optimizer update ops (one Mul + AssignSub/AssignAdd pair per
    /// weight tensor, as TF's resource-variable SGD/momentum does) and
    /// produce the final graph.
    pub fn finish(mut self) -> Graph {
        let mut opt_ops = Vec::new();
        for (i, &w) in self.weight_tensors.iter().enumerate() {
            let layer = format!("training/update_{i}");
            opt_ops.push(Op::new(
                "Mul",
                layer.clone(),
                OpClass::Optimizer,
                w,
                3.0 * 4.0 * w,
                vec![w as usize],
            ));
            opt_ops.push(Op::new(
                "AssignSubVariableOp",
                layer.clone(),
                OpClass::Optimizer,
                w,
                3.0 * 4.0 * w,
                vec![w as usize],
            ));
            opt_ops.push(Op::new(
                "AssignAddVariableOp",
                layer,
                OpClass::Optimizer,
                w,
                3.0 * 4.0 * w,
                vec![w as usize],
            ));
        }
        // One global gradient-norm reduction (gradient clipping / metrics).
        let total_w: f64 = self.weight_tensors.iter().sum();
        opt_ops.push(Op::new(
            "Sum",
            "training/grad_norm".to_string(),
            OpClass::Reduction,
            2.0 * total_w,
            4.0 * total_w,
            vec![1],
        ));
        self.opt = opt_ops;

        let mut ops = self.fwd;
        // backward runs in reverse layer order
        let mut bwd = self.bwd;
        bwd.reverse();
        ops.extend(bwd);
        ops.extend(self.opt);

        Graph {
            model: self.model,
            batch: self.batch,
            pixels: self.pixels,
            ops,
            weight_elems: self.weight_tensors.iter().sum(),
            act_elems: self.act_elems,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut t = Tape::new(ModelId::MnistCnn, 8, 32);
        t.conv(3, 16, 1, Pad::Same).unwrap();
        t.act();
        t.maxpool(2, 2, Pad::Valid).unwrap();
        t.classifier(10)
    }

    #[test]
    fn conv_shapes_same_vs_valid() {
        let mut t = Tape::new(ModelId::MnistCnn, 1, 32);
        t.conv(3, 8, 1, Pad::Same).unwrap();
        assert_eq!(t.hw(), (32, 32));
        t.conv(3, 8, 2, Pad::Same).unwrap();
        assert_eq!(t.hw(), (16, 16));
        t.conv(5, 8, 1, Pad::Valid).unwrap();
        assert_eq!(t.hw(), (12, 12));
    }

    #[test]
    fn valid_underflow_is_error() {
        let mut t = Tape::new(ModelId::LeNet5, 1, 4);
        assert!(t.conv(5, 8, 1, Pad::Valid).is_err());
    }

    #[test]
    fn first_conv_has_no_input_grad() {
        let g = tiny_graph();
        assert!(!g.ops.iter().any(|o| o.name == "Conv2DBackpropInput"));
        assert!(g.ops.iter().any(|o| o.name == "Conv2DBackpropFilter"));
    }

    #[test]
    fn conv_flops_formula() {
        let mut t = Tape::new(ModelId::MnistCnn, 2, 8);
        t.conv(3, 4, 1, Pad::Same).unwrap();
        let conv = t.fwd.iter().find(|o| o.name == "Conv2D").unwrap();
        // 2 * B*OH*OW*Cout * K*K*Cin = 2 * 2*8*8*4 * 9*3
        assert_eq!(conv.flops, 2.0 * (2 * 8 * 8 * 4) as f64 * 27.0);
    }

    #[test]
    fn layer_names_increment() {
        let mut t = Tape::new(ModelId::MnistCnn, 1, 16);
        t.conv(3, 4, 1, Pad::Same).unwrap();
        t.conv(3, 4, 1, Pad::Same).unwrap();
        let names: Vec<&str> = t
            .fwd
            .iter()
            .filter(|o| o.name == "Conv2D")
            .map(|o| o.layer.as_str())
            .collect();
        assert_eq!(names, vec!["conv2d_0", "conv2d_1"]);
    }

    #[test]
    fn graph_memory_positive_and_scales() {
        let small = tiny_graph();
        let mut t = Tape::new(ModelId::MnistCnn, 128, 32);
        t.conv(3, 16, 1, Pad::Same).unwrap();
        t.act();
        t.maxpool(2, 2, Pad::Valid).unwrap();
        let big = t.classifier(10);
        assert!(big.memory_bytes() > small.memory_bytes());
    }

    #[test]
    fn classifier_emits_loss_and_optimizer() {
        let g = tiny_graph();
        for name in ["Softmax", "SoftmaxCrossEntropyWithLogits", "AssignSubVariableOp", "Sum"] {
            assert!(g.ops.iter().any(|o| o.name == name), "{name}");
        }
    }
}
