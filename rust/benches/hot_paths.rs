//! `cargo bench --bench hot_paths` — micro-benchmarks of every hot path,
//! with a hand-rolled harness (offline environment: no criterion).
//!
//! Per layer (DESIGN.md §Perf):
//!   L3: simulator throughput, feature vectorization, clustering, forest
//!       prediction, JSON protocol parse, end-to-end serve round trip;
//!   L2/L1 (through PJRT): MLP forward (batched + per-row amortized),
//!       Adam train step, batched Levenshtein artifact vs native rust.

use repro::data::Corpus;
use repro::features::FeatureSpace;
use repro::gpu::Instance;
use repro::ml::RandomForest;
use repro::models::{build, ModelId};
use repro::runtime::MlpState;
use repro::sim::{self, Workload};
use repro::util::Rng64;
use std::time::Instant;

/// Run `f` repeatedly for ~`budget_ms`, report ns/iter and iters/s.
fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> f64 {
    // warmup
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed() < budget {
        f();
        iters += 1;
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    println!(
        "  {name:44} {:>12.2} us/iter {:>14.0} iters/s",
        per * 1e6,
        1.0 / per
    );
    per
}

fn main() {
    println!("== hot_paths bench (hand-rolled harness) ==");
    let rt = repro::runtime::load_default().expect("make artifacts first");
    let meta = rt.meta.clone();

    // ---------------- L3: simulator substrate ----------------
    println!("[L3] simulator:");
    let g_r50 = build(ModelId::ResNet50, 32, 128).unwrap();
    bench("sim::execute ResNet50 b32 p128 (586 ops)", 400, || {
        std::hint::black_box(sim::execute(&g_r50, Instance::P3.spec()));
    });
    bench("graph build ResNet50 b32 p128", 400, || {
        std::hint::black_box(build(ModelId::ResNet50, 32, 128).unwrap());
    });
    bench("run_workload VGG16 b16 p64 (build+sim)", 400, || {
        std::hint::black_box(sim::run_workload(&Workload::new(ModelId::Vgg16, 16, 64), Instance::G4dn));
    });

    // ---------------- L3: feature pipeline ----------------
    println!("[L3] features:");
    let vocab_owned: Vec<String> = Corpus::generate(&[Instance::G4dn]).vocabulary();
    let vocab: Vec<&str> = vocab_owned.iter().map(|s| s.as_str()).collect();
    bench("hierarchical clustering (full vocabulary)", 400, || {
        std::hint::black_box(repro::features::average_linkage_clusters(&vocab, 6.0));
    });
    let fs = FeatureSpace::fit(&vocab, true, meta.d_feat).unwrap();
    let profile = sim::run_workload(&Workload::new(ModelId::InceptionV3, 16, 224), Instance::G4dn)
        .unwrap()
        .profile
        .aggregated();
    bench("FeatureSpace::vectorize (seen ops)", 300, || {
        std::hint::black_box(fs.vectorize(&profile));
    });
    bench("levenshtein rust (op-name pair)", 200, || {
        std::hint::black_box(repro::features::levenshtein(
            "DepthwiseConv2dNativeBackpropFilter",
            "Conv2DBackpropFilter",
        ));
    });

    // ---------------- L3: classical ML ----------------
    println!("[L3] classical ML:");
    let mut rng = Rng64::new(5);
    let xs: Vec<Vec<f64>> = (0..800)
        .map(|_| (0..meta.d_feat).map(|_| rng.range(0.0, 100.0)).collect())
        .collect();
    let ys: Vec<f64> = xs.iter().map(|r| r.iter().sum::<f64>() / 7.0).collect();
    let forest = RandomForest::fit(&xs, &ys, 100, 3).unwrap();
    bench("RandomForest::predict_one (100 trees)", 300, || {
        std::hint::black_box(forest.predict_one(&xs[0]));
    });
    bench("RandomForest::fit 800x48 (100 trees)", 1500, || {
        std::hint::black_box(RandomForest::fit(&xs, &ys, 100, 3).unwrap());
    });

    // ---------------- L1/L2 through PJRT ----------------
    println!("[L1/L2] HLO artifacts via PJRT:");
    let state = MlpState::init(meta.d_feat, 7);
    let x_pred: Vec<f32> = (0..meta.b_pred * meta.d_feat)
        .map(|i| (i % 97) as f32 / 97.0)
        .collect();
    let per = bench("mlp_forward artifact (b_pred=64 rows)", 600, || {
        std::hint::black_box(rt.mlp_forward(&state.params, &x_pred).unwrap());
    });
    println!(
        "  {:44} {:>12.2} us/row (amortized)",
        "  -> per-prediction cost",
        per * 1e6 / meta.b_pred as f64
    );
    let mut tstate = MlpState::init(meta.d_feat, 8);
    let x_tr: Vec<f32> = (0..meta.b_train * meta.d_feat)
        .map(|i| (i % 89) as f32 / 89.0)
        .collect();
    let y_tr: Vec<f32> = (0..meta.b_train).map(|i| 1.0 + i as f32).collect();
    bench("mlp train_step artifact (Adam, b=32)", 600, || {
        std::hint::black_box(rt.train_step(&mut tstate, &x_tr, &y_tr).unwrap());
    });
    let pairs: Vec<(&str, &str)> = (0..meta.lev_k)
        .map(|i| {
            if i % 2 == 0 {
                ("MaxPoolGrad", "AvgPoolGrad")
            } else {
                ("FusedBatchNormV3", "FusedBatchNormGradV3")
            }
        })
        .collect();
    let per_lev = bench("levenshtein artifact (64 pairs)", 600, || {
        std::hint::black_box(rt.levenshtein_strs(&pairs).unwrap());
    });
    println!(
        "  {:44} {:>12.2} us/pair (amortized)",
        "  -> per-pair cost",
        per_lev * 1e6 / meta.lev_k as f64
    );

    // ---------------- protocol ----------------
    println!("[L3] coordinator protocol:");
    let line = r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":42.5,"profile":{"Conv2D":286.0,"Relu":26.0,"MaxPool":14.0,"FusedBatchNormV3":33.0}}"#;
    bench("Request::parse (predict line)", 200, || {
        std::hint::black_box(repro::coordinator::Request::parse(line).unwrap());
    });

    println!("== hot_paths done ==");
}
