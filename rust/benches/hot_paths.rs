//! `cargo bench --bench hot_paths` — micro-benchmarks of every hot path,
//! with a hand-rolled harness (offline environment: no criterion).
//!
//! Per layer (DESIGN.md §Perf):
//!   L3: simulator throughput, feature vectorization, clustering, forest
//!       fit + batched prediction, JSON protocol parse;
//!   L2/L1 (through PJRT, skipped when the backend is unavailable):
//!       MLP forward (batched + per-row amortized), Adam train step,
//!       batched Levenshtein artifact vs native rust.
//!
//! Results are also written as machine-readable `BENCH_hot_paths.json`
//! (name -> ns/iter) at the repository root so the perf trajectory is
//! tracked commit to commit.

use repro::data::Corpus;
use repro::features::FeatureSpace;
use repro::gpu::Instance;
use repro::ml::{FeatureMatrix, RandomForest};
use repro::models::{build, ModelId};
use repro::sim::{self, Workload};
use repro::util::{Json, Rng64};
use std::time::Instant;

/// `BENCH_SMOKE=1` (the CI bench-smoke job) caps every measurement budget
/// so the whole suite finishes in seconds — the JSON artifact is then a
/// liveness/trajectory record, not a precision measurement.
fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Run `f` repeatedly for ~`budget_ms`, report ns/iter and iters/s, and
/// record the result for the JSON dump.
fn bench<F: FnMut()>(
    results: &mut Vec<(String, f64)>,
    name: &str,
    budget_ms: u64,
    mut f: F,
) -> f64 {
    // warmup
    for _ in 0..3 {
        f();
    }
    let budget_ms = if smoke() { budget_ms.min(30) } else { budget_ms };
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed() < budget {
        f();
        iters += 1;
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    println!(
        "  {name:48} {:>12.2} us/iter {:>14.0} iters/s",
        per * 1e6,
        1.0 / per
    );
    results.push((name.to_string(), per * 1e9));
    per
}

fn main() {
    println!("== hot_paths bench (hand-rolled harness) ==");
    let mut results: Vec<(String, f64)> = Vec::new();
    let rt = repro::runtime::load_default().ok();
    let d_feat = rt.as_ref().map(|r| r.meta.d_feat).unwrap_or(48);
    if rt.is_none() {
        println!("(PJRT runtime unavailable — skipping the L1/L2 artifact benches)");
    }

    // ---------------- L3: simulator substrate ----------------
    println!("[L3] simulator:");
    let g_r50 = build(ModelId::ResNet50, 32, 128).unwrap();
    bench(&mut results, "sim::execute ResNet50 b32 p128 (586 ops)", 400, || {
        std::hint::black_box(sim::execute(&g_r50, Instance::P3.spec()));
    });
    bench(&mut results, "graph build ResNet50 b32 p128", 400, || {
        std::hint::black_box(build(ModelId::ResNet50, 32, 128).unwrap());
    });
    bench(&mut results, "run_workload VGG16 b16 p64 (build+sim)", 400, || {
        std::hint::black_box(sim::run_workload(&Workload::new(ModelId::Vgg16, 16, 64), Instance::G4dn));
    });

    // ---------------- L3: feature pipeline ----------------
    println!("[L3] features:");
    let vocab_owned: Vec<String> = Corpus::generate(&[Instance::G4dn]).vocabulary();
    let vocab: Vec<&str> = vocab_owned.iter().map(|s| s.as_str()).collect();
    bench(&mut results, "hierarchical clustering (full vocabulary)", 400, || {
        std::hint::black_box(repro::features::average_linkage_clusters(&vocab, 6.0));
    });
    let fs = FeatureSpace::fit(&vocab, true, d_feat).unwrap();
    let profile = sim::run_workload(&Workload::new(ModelId::InceptionV3, 16, 224), Instance::G4dn)
        .unwrap()
        .profile
        .aggregated();
    bench(&mut results, "FeatureSpace::vectorize (seen ops)", 300, || {
        std::hint::black_box(fs.vectorize(&profile));
    });
    bench(&mut results, "levenshtein rust (op-name pair)", 200, || {
        std::hint::black_box(repro::features::levenshtein(
            "DepthwiseConv2dNativeBackpropFilter",
            "Conv2DBackpropFilter",
        ));
    });

    // ---------------- L3: classical ML ----------------
    println!("[L3] classical ML:");
    let mut rng = Rng64::new(5);
    let rows: Vec<Vec<f64>> = (0..800)
        .map(|_| (0..d_feat).map(|_| rng.range(0.0, 100.0)).collect())
        .collect();
    let xs = FeatureMatrix::from_rows(&rows).unwrap();
    let ys: Vec<f64> = rows.iter().map(|r| r.iter().sum::<f64>() / 7.0).collect();
    let forest = RandomForest::fit(&xs, &ys, 100, 3).unwrap();
    bench(&mut results, "RandomForest::predict_one (100 trees)", 300, || {
        std::hint::black_box(forest.predict_one(&rows[0]));
    });
    let batch_rows: Vec<Vec<f64>> = rows.iter().take(64).cloned().collect();
    let batch = FeatureMatrix::from_rows(&batch_rows).unwrap();
    let per_batch = bench(
        &mut results,
        "RandomForest::predict_batch (100 trees, 64 rows)",
        300,
        || {
            std::hint::black_box(forest.predict_batch(&batch));
        },
    );
    println!(
        "  {:48} {:>12.2} us/row (amortized)",
        "  -> per-row cost",
        per_batch * 1e6 / 64.0
    );
    bench(&mut results, "RandomForest::fit 800x48 (100 trees)", 1500, || {
        std::hint::black_box(RandomForest::fit(&xs, &ys, 100, 3).unwrap());
    });

    // ---------------- L1/L2 through PJRT ----------------
    if let Some(rt) = &rt {
        use repro::runtime::MlpState;
        let meta = rt.meta.clone();
        println!("[L1/L2] HLO artifacts via PJRT:");
        let state = MlpState::init(meta.d_feat, 7);
        let x_pred: Vec<f32> = (0..meta.b_pred * meta.d_feat)
            .map(|i| (i % 97) as f32 / 97.0)
            .collect();
        let per = bench(&mut results, "mlp_forward artifact (b_pred=64 rows)", 600, || {
            std::hint::black_box(rt.mlp_forward(&state.params, &x_pred).unwrap());
        });
        println!(
            "  {:48} {:>12.2} us/row (amortized)",
            "  -> per-prediction cost",
            per * 1e6 / meta.b_pred as f64
        );
        let mut tstate = MlpState::init(meta.d_feat, 8);
        let x_tr: Vec<f32> = (0..meta.b_train * meta.d_feat)
            .map(|i| (i % 89) as f32 / 89.0)
            .collect();
        let y_tr: Vec<f32> = (0..meta.b_train).map(|i| 1.0 + i as f32).collect();
        bench(&mut results, "mlp train_step artifact (Adam, b=32)", 600, || {
            std::hint::black_box(rt.train_step(&mut tstate, &x_tr, &y_tr).unwrap());
        });
        let pairs: Vec<(&str, &str)> = (0..meta.lev_k)
            .map(|i| {
                if i % 2 == 0 {
                    ("MaxPoolGrad", "AvgPoolGrad")
                } else {
                    ("FusedBatchNormV3", "FusedBatchNormGradV3")
                }
            })
            .collect();
        let per_lev = bench(&mut results, "levenshtein artifact (64 pairs)", 600, || {
            std::hint::black_box(rt.levenshtein_strs(&pairs).unwrap());
        });
        println!(
            "  {:48} {:>12.2} us/pair (amortized)",
            "  -> per-pair cost",
            per_lev * 1e6 / meta.lev_k as f64
        );
    }

    // ---------------- protocol / wire path ----------------
    println!("[L3] coordinator wire path:");
    let line = r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":42.5,"profile":{"Conv2D":286.0,"Relu":26.0,"MaxPool":14.0,"FusedBatchNormV3":33.0}}"#;
    bench(&mut results, "Request::parse (predict line, fresh scratch)", 200, || {
        std::hint::black_box(repro::coordinator::Request::parse(line).unwrap());
    });
    {
        use repro::coordinator::{parse_line, ParsedLine, Response, WireScratch};
        use repro::predictor::Member;
        // the serving configuration: per-connection scratch, reused
        let mut scratch = WireScratch::default();
        bench(&mut results, "wire parse_line (reused scratch, zero-alloc)", 200, || {
            let parsed = parse_line(line, &mut scratch).unwrap();
            std::hint::black_box(matches!(parsed, ParsedLine::Predict(_)));
        });
        // what the wire layer replaced: full DOM materialization
        bench(&mut results, "DOM Json::parse (same line, old wire path)", 200, || {
            std::hint::black_box(Json::parse(line).unwrap());
        });
        let mut out = Vec::new();
        let predict = Response::Prediction {
            latency_ms: 123.456,
            member: Member::Forest,
        };
        bench(&mut results, "wire encode predict response (reused buf)", 200, || {
            predict.encode_line(&mut out);
            std::hint::black_box(out.len());
        });
        let stats = Response::Stats {
            requests: 123_456,
            artifact_batches: 789,
            avg_batch_fill: 2.5,
            overloaded: 3,
            predict_lanes: 8,
            cache_hits: 100_000,
            cache_misses: 23_456,
            registry_epoch: 2,
            last_reload: 1_753_600_000_123,
            open_conns: 512,
            active_conns: 64,
            idle_conns: 448,
            lane_restarts: 0,
            evictions: 17,
            hints_applied: 9,
            reactor_threads: 2,
            uptime_s: 3600.5,
            version: env!("CARGO_PKG_VERSION"),
        };
        bench(&mut results, "wire encode stats response (reused buf)", 200, || {
            stats.encode_line(&mut out);
            std::hint::black_box(out.len());
        });
        // float formatter in isolation (shortest-round-trip Grisu2)
        let mut fbuf = Vec::new();
        let mut x = 0.1f64;
        bench(&mut results, "write_f64 (grisu2 shortest round-trip)", 200, || {
            fbuf.clear();
            repro::util::json_stream::write_f64(&mut fbuf, x);
            x += 1.0 / 3.0;
            std::hint::black_box(fbuf.len());
        });
    }

    // ---------------- advisor ----------------
    println!("[L3] advisor:");
    {
        let mut rng = Rng64::new(17);
        let pts: Vec<(f64, f64)> = (0..4096)
            .map(|_| (rng.range(0.1, 10.0), rng.range(0.01, 1.0)))
            .collect();
        bench(&mut results, "advisor::pareto_frontier (4096 pts)", 300, || {
            std::hint::black_box(repro::advisor::pareto_frontier(&pts));
        });
    }
    if let Some(rt) = &rt {
        use repro::advisor::{CacheStats, EndpointProfiles, PredictionCache, SweepRequest};
        use repro::predictor::{Profet, TrainOptions};
        use repro::sim::ScalingTable;
        // tiny advisor-serving stack: 1 anchor -> 1 target, small ensemble
        let corpus2 = Corpus::generate(&[Instance::G4dn, Instance::P3]);
        let (train_idx, _) = corpus2.split_random(0.2, 7);
        let opts = TrainOptions {
            anchors: vec![Instance::G4dn],
            targets: vec![Instance::P3],
            n_trees: 10,
            dnn_epochs: 5,
            ..Default::default()
        };
        let profet = Profet::train(rt, &corpus2, &train_idx, &opts).unwrap();
        let endpoint = |batch: usize| {
            let w = Workload::new(ModelId::ResNet18, batch, 64);
            let run = sim::run_workload(&w, Instance::G4dn).unwrap();
            (run.profile.aggregated(), run.latency_ms)
        };
        let (p_min, l_min) = endpoint(16);
        let (p_max, l_max) = endpoint(256);
        let query = SweepRequest {
            anchor: Instance::G4dn,
            pixels: 64,
            batch: EndpointProfiles {
                profile_min: p_min,
                lat_min: l_min,
                profile_max: p_max,
                lat_max: l_max,
            },
            pixel: None,
            targets: Vec::new(),
            batches: Vec::new(),
            pixel_sizes: Vec::new(),
            gpu_counts: vec![1, 2],
            include_spot: true,
        };
        let scaling = ScalingTable::new();
        let stats = CacheStats::default();
        // cold: fresh cache every iteration (phase-1 executes each time)
        bench(&mut results, "advisor_sweep cold (2 targets, full grid)", 600, || {
            let cache = PredictionCache::new(16, 4096);
            std::hint::black_box(
                repro::advisor::sweep(rt, 0, &profet, &cache, &stats, &scaling, &query).unwrap(),
            );
        });
        // warm: shared cache, phase-1 short-circuits to lookups
        let cache = PredictionCache::new(16, 4096);
        bench(&mut results, "advisor_sweep warm (cache hits)", 400, || {
            std::hint::black_box(
                repro::advisor::sweep(rt, 0, &profet, &cache, &stats, &scaling, &query).unwrap(),
            );
        });

        // ---------------- engine pool (serving lanes) ----------------
        // predict round-trip latency through the replica pool, idle and
        // with the advisor lane saturated by back-to-back sweeps — the
        // two numbers should be within noise of each other (a sweep on
        // its own lane must not tax predict traffic)
        println!("[L3] engine pool:");
        {
            use repro::coordinator::{EnginePool, Job, PoolOptions, PredictRequest};
            use std::sync::atomic::{AtomicBool, Ordering};
            use std::sync::mpsc::channel;
            use std::sync::Arc;
            let model_dir = std::env::temp_dir().join("repro_bench_pool_models");
            std::fs::remove_dir_all(&model_dir).ok();
            profet.save(&model_dir).unwrap();
            let pool = Arc::new(
                EnginePool::spawn(
                    repro::runtime::default_artifact_dir(),
                    model_dir.clone(),
                    &PoolOptions {
                        predict_lanes: 2,
                        ..PoolOptions::default()
                    },
                )
                .unwrap(),
            );
            let (p64, l64) = endpoint(64);
            let predict = PredictRequest {
                anchor: Instance::G4dn,
                target: Instance::P3,
                anchor_latency_ms: l64,
                profile: p64,
            };
            let rtt = |pool: &EnginePool| {
                let (tx, rx) = channel();
                let snap = pool.registry().snapshot();
                pool.submit(Job::Predict(predict.clone(), snap, tx)).unwrap();
                rx.recv().unwrap()
            };
            bench(&mut results, "engine_pool predict rtt (advisor idle)", 400, || {
                std::hint::black_box(rtt(&pool));
            });
            // the full serving wire path: decode + cache fast path +
            // encode, with per-connection scratch reuse — after the
            // first miss every round trip is a zero-allocation cache hit
            {
                let wire_line = repro::coordinator::Request::Predict(predict.clone())
                    .to_json()
                    .to_string();
                let mut cs = repro::coordinator::ConnScratch::default();
                repro::coordinator::respond(&pool, &wire_line, &mut cs); // seed the cache
                bench(
                    &mut results,
                    "route predict full wire rtt (warm cache, zero-alloc)",
                    300,
                    || {
                        repro::coordinator::respond(&pool, &wire_line, &mut cs);
                        std::hint::black_box(cs.out.len());
                    },
                );
            }
            // feeder: saturate the advisor lane for the whole measurement
            let stop = Arc::new(AtomicBool::new(false));
            let feeder = {
                let stop = stop.clone();
                let pool = pool.clone();
                let query = query.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let (tx, rx) = channel();
                        let job = Job::Recommend {
                            query: query.clone(),
                            top_k: 0,
                            snap: pool.registry().snapshot(),
                            reply: tx,
                        };
                        if pool.submit(job).is_ok() {
                            let _ = rx.recv();
                        }
                    }
                })
            };
            bench(
                &mut results,
                "engine_pool predict rtt (advisor sweeping)",
                400,
                || {
                    std::hint::black_box(rtt(&pool));
                },
            );
            stop.store(true, Ordering::Relaxed);
            feeder.join().unwrap();
            drop(pool);
            std::fs::remove_dir_all(&model_dir).ok();
        }
    }

    // ---------------- machine-readable dump ----------------
    let mut o = Json::obj();
    for (name, ns) in &results {
        o.set(name, Json::Num(*ns));
    }
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_paths.json");
    match std::fs::write(out_path, o.to_string()) {
        Ok(()) => println!("wrote {out_path} ({} entries, ns/iter)", results.len()),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }

    println!("== hot_paths done ==");
}
