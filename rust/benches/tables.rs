//! `cargo bench --bench tables` — regenerates EVERY table and figure of
//! the paper's evaluation section (the DESIGN.md experiment index) and
//! prints the same rows/series the paper reports, with CHECK lines for
//! each paper-shape assertion.
//!
//! Set REPRO_FAST=1 for a quick (reduced trees/epochs) pass.

fn main() {
    let t0 = std::time::Instant::now();
    let mut ctx = repro::evalx::Ctx::build().expect("run `make artifacts` first");
    println!(
        "corpus: {} workloads / {} observations (train {}, test {})\n",
        ctx.corpus.entries.len(),
        ctx.corpus.n_observations(),
        ctx.train_idx.len(),
        ctx.test_idx.len()
    );
    let report = repro::evalx::run("all", &mut ctx).expect("eval failed");
    println!("{report}");
    let fails = report.matches("[FAIL]").count();
    let passes = report.matches("[PASS]").count();
    println!(
        "=== tables bench: {passes} checks passed, {fails} failed, {:.1}s ===",
        t0.elapsed().as_secs_f64()
    );
    if fails > 0 {
        std::process::exit(1);
    }
}
