//! Integration: the rust runtime executes the AOT HLO artifacts and the
//! numerics match the python oracles' contracts.
//!
//! Requires `make artifacts` and the PJRT backend (each test skips with a
//! note otherwise — the offline build links the xla shim).

use repro::runtime::{self, MlpState};

fn rt() -> Option<repro::runtime::Runtime> {
    match runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: runtime unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn loads_and_reports_platform() {
    let Some(rt) = rt() else { return };
    let plat = rt.platform().to_lowercase();
    assert!(plat.contains("cpu") || plat.contains("host"), "{plat}");
    assert_eq!(rt.meta.param_count, runtime::mlp_param_count(rt.meta.d_feat));
}

#[test]
fn mlp_forward_zero_params_zero_output() {
    let Some(rt) = rt() else { return };
    let m = &rt.meta;
    let params = vec![0f32; m.param_count];
    let x = vec![1f32; m.b_pred * m.d_feat];
    let y = rt.mlp_forward(&params, &x).unwrap();
    assert_eq!(y.len(), m.b_pred);
    assert!(y.iter().all(|v| *v == 0.0));
}

#[test]
fn mlp_forward_deterministic_and_batch_consistent() {
    let Some(rt) = rt() else { return };
    let m = rt.meta.clone();
    let state = MlpState::init(m.d_feat, 42);
    let mut x = vec![0f32; m.b_pred * m.d_feat];
    let mut rng = repro::util::Rng64::new(7);
    for v in x.iter_mut() {
        *v = rng.normal() as f32;
    }
    let y1 = rt.mlp_forward(&state.params, &x).unwrap();
    let y2 = rt.mlp_forward(&state.params, &x).unwrap();
    assert_eq!(y1, y2, "deterministic");
    // permuting rows permutes outputs (no cross-batch leakage)
    let d = m.d_feat;
    let mut xp = x.clone();
    xp.copy_within(0..d, (m.b_pred - 1) * d);
    xp.copy_within((m.b_pred - 1) * d..m.b_pred * d, 0);
    // swap rows 0 and last via rebuild
    let mut xs = x.clone();
    for j in 0..d {
        xs.swap(j, (m.b_pred - 1) * d + j);
    }
    let ys = rt.mlp_forward(&state.params, &xs).unwrap();
    assert!((ys[0] - y1[m.b_pred - 1]).abs() < 1e-5);
    assert!((ys[m.b_pred - 1] - y1[0]).abs() < 1e-5);
    for i in 1..m.b_pred - 1 {
        assert!((ys[i] - y1[i]).abs() < 1e-5);
    }
}

#[test]
fn train_step_reduces_loss_on_learnable_target() {
    let Some(rt) = rt() else { return };
    let m = rt.meta.clone();
    let mut state = MlpState::init(m.d_feat, 1);
    let mut rng = repro::util::Rng64::new(11);
    let x: Vec<f32> = (0..m.b_train * m.d_feat)
        .map(|_| rng.normal() as f32)
        .collect();
    let w: Vec<f32> = (0..m.d_feat).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..m.b_train)
        .map(|i| {
            let row = &x[i * m.d_feat..(i + 1) * m.d_feat];
            row.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>().abs() + 1.0
        })
        .collect();
    let first = rt.train_step(&mut state, &x, &y).unwrap();
    let mut last = first;
    for _ in 0..80 {
        last = rt.train_step(&mut state, &x, &y).unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first * 0.9, "loss {first} -> {last}");
    assert_eq!(state.t, 81.0);
}

#[test]
fn levenshtein_matches_known_distances() {
    let Some(rt) = rt() else { return };
    // Paper's worked examples (Sec III-B1).
    let pairs = [
        ("ReLU", "ReLU6"),
        ("ReLU", "Conv2D"),
        ("MaxPoolGrad", "AvgPoolGrad"),
        ("MatMul", "MaxPool"),
        ("", ""),
        ("FusedBatchNormV3", "FusedBatchNormGradV3"),
    ];
    let got = rt.levenshtein_strs(&pairs).unwrap();
    assert_eq!(got, vec![1, 6, 3, 4, 0, 4]);
}

#[test]
fn levenshtein_chunks_many_pairs() {
    let Some(rt) = rt() else { return };
    let k = rt.meta.lev_k;
    // more pairs than one artifact batch → exercises chunking
    let names: Vec<String> = (0..(k + 10)).map(|i| format!("Op{i}")).collect();
    let pairs: Vec<(&str, &str)> = names.iter().map(|n| (n.as_str(), "Op0")).collect();
    let got = rt.levenshtein_strs(&pairs).unwrap();
    assert_eq!(got.len(), k + 10);
    assert_eq!(got[0], 0);
    // d("Op7", "Op0") = 1; d("Op17", "Op0") in {1,2}
    assert_eq!(got[7], 1);
    assert!(got[17] >= 1 && got[17] <= 2);
}
