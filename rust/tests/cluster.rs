//! Deterministic cluster harness tests for the `repro route` tier
//! (tentpole of the multi-node serving PR): an in-process router over
//! real multi-backend TCP listeners on ephemeral ports, with scripted
//! membership changes — no sleeps-as-synchronization beyond bounded
//! polls, no runtime, no model artifacts.
//!
//! Covered invariants:
//! * sharded ops land on exactly the backend the [`Ring`] oracle names,
//! * killing a backend loses zero replies (failover), is surfaced as an
//!   ejection in `cluster_stats`, and a rejoin restores the shard and
//!   replays the cache hints buffered during the outage,
//! * a fleet publish (`ingest` + `onboard`/`reload`) brings every node
//!   to the same `registry_epoch`; a rejecting node aborts the publish
//!   with a structured per-node report and the old epoch everywhere,
//! * every `cluster_stats` snapshot is internally consistent under
//!   concurrent load (the one-lock torn-read guarantee).
//!
//! Chaos-flavored coverage (failpoint-injected peer partitions) lives
//! in `tests/chaos.rs` (`chaos_cluster_*`, single-threaded); this
//! binary stays failpoint-free so the default parallel sweep can run it.

mod cluster_util;

use cluster_util::{ingest_line, predict_line, send, shard_pairs, StubBackend};
use repro::coordinator::cluster::Ring;
use repro::coordinator::{serve_cluster, RouteHandle, RouteOptions};
use repro::util::Json;
use std::time::{Duration, Instant};

/// Boot `n` stub backends and a router over them.
fn boot(n: usize, probe_ms: u64) -> (Vec<StubBackend>, RouteHandle, String) {
    let stubs: Vec<StubBackend> = (0..n).map(|_| StubBackend::start()).collect();
    let handle = serve_cluster(RouteOptions {
        addr: "127.0.0.1:0".into(),
        backends: stubs.iter().map(|s| s.addr()).collect(),
        probe_interval: Duration::from_millis(probe_ms),
        fail_threshold: 2,
        call_timeout: Duration::from_millis(500),
    })
    .unwrap();
    let addr = handle.addr().to_string();
    (stubs, handle, addr)
}

/// Bounded poll — the only waiting primitive these tests use.
fn wait_until(what: &str, mut done: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !done() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Wait for the prober's *second* round: the prober is sequential, so a
/// second probe arriving at a stub proves the first round's bookkeeping
/// (each backend's `registry_epoch`, which hint buffering needs) is
/// already recorded under the router lock.
fn wait_first_probe(stubs: &[StubBackend]) {
    wait_until("two full probe rounds", || {
        stubs.iter().all(|s| s.requests() >= 2)
    });
}

fn cluster_stats(addr: &str) -> Json {
    send(addr, r#"{"op":"cluster_stats"}"#)
}

#[test]
fn cluster_shard_routing_matches_the_ring_oracle() {
    let (stubs, handle, addr) = boot(3, 500);
    let oracle = Ring::new(stubs.iter().map(|s| s.addr()).collect());

    for (a, t) in shard_pairs() {
        let resp = send(&addr, &predict_line(a, t));
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        let served = resp.req_str("served_by").unwrap();
        let expect = oracle.backends()[oracle.owner(Ring::shard_key(a, t)).unwrap()].as_str();
        assert_eq!(served, expect, "({a},{t}) must land on its ring owner");
        // and routing is stable: the same key lands on the same node
        let again = send(&addr, &predict_line(a, t));
        assert_eq!(again.req_str("served_by").unwrap(), expect);
    }

    // shard diversity: a 30-pair sweep over a 3-node ring uses every node
    assert!(
        stubs.iter().all(|s| s.predicts() > 0),
        "every backend must own some shard: {:?}",
        stubs.iter().map(|s| s.predicts()).collect::<Vec<_>>()
    );

    let st = cluster_stats(&addr);
    assert_eq!(st.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(st.req_f64("healthy_backends").unwrap() as usize, 3);
    assert_eq!(st.req_f64("no_backend").unwrap() as u64, 0);
    handle.stop();
}

#[test]
fn cluster_backend_kill_fails_over_ejects_and_rejoins_with_hint_replay() {
    let (stubs, handle, addr) = boot(3, 25);
    wait_first_probe(&stubs);
    let oracle = Ring::new(stubs.iter().map(|s| s.addr()).collect());

    // pick a pair owned by backend 0 (ring order == sorted stub addrs)
    let victim_addr = oracle.backends()[0].clone();
    let victim = stubs.iter().find(|s| s.addr() == victim_addr).unwrap();
    let (a, t) = shard_pairs()
        .into_iter()
        .find(|(a, t)| oracle.owner(Ring::shard_key(a, t)) == Some(0))
        .expect("30 pairs must hit every node of a 3-node ring");

    // baseline: the owner serves its shard
    let resp = send(&addr, &predict_line(a, t));
    assert_eq!(resp.req_str("served_by").unwrap(), victim_addr);

    victim.kill();

    // zero lost replies: the very next predict fails over to a fallback
    // owner before any probe has noticed the death
    let resp = send(&addr, &predict_line(a, t));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert_ne!(resp.req_str("served_by").unwrap(), victim_addr);

    // the fallback-served predict left a cache hint for the dead owner
    let st = cluster_stats(&addr);
    assert!(st.req_f64("retries").unwrap() >= 1.0, "{st:?}");
    assert!(st.req_f64("hints_pending").unwrap() >= 1.0, "{st:?}");

    // the prober ejects it after fail_threshold consecutive misses
    wait_until("the ejection to surface in cluster_stats", || {
        let st = cluster_stats(&addr);
        st.req_f64("healthy_backends").unwrap() as usize == 2
            && st.req_f64("ejections").unwrap() >= 1.0
    });
    // while ejected, its shard keeps answering from fallback owners
    let resp = send(&addr, &predict_line(a, t));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert_ne!(resp.req_str("served_by").unwrap(), victim_addr);

    victim.revive();
    wait_until("the rejoin + hint replay", || {
        let st = cluster_stats(&addr);
        st.req_f64("healthy_backends").unwrap() as usize == 3
            && st.req_f64("rejoins").unwrap() >= 1.0
            && st.req_f64("hints_replayed").unwrap() >= 1.0
    });
    assert!(victim.hints() >= 1, "the rejoined owner must receive its buffered hints");

    // the shard is home again
    let resp = send(&addr, &predict_line(a, t));
    assert_eq!(resp.req_str("served_by").unwrap(), victim_addr);
    handle.stop();
}

#[test]
fn cluster_publish_reaches_epoch_agreement_or_reports_per_node() {
    let (stubs, handle, addr) = boot(3, 500);

    // ingest fans out to every node's staging area
    let resp = send(&addr, &ingest_line("g4dn", "p2"));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    assert!(stubs.iter().all(|s| s.ingests() == 1), "ingest must broadcast");

    // a clean onboard brings the whole fleet to the same new epoch
    let ob = send(&addr, r#"{"op":"onboard","anchor":"g4dn","target":"p2"}"#);
    assert_eq!(ob.get("ok").and_then(Json::as_bool), Some(true), "{ob:?}");
    assert_eq!(ob.req_f64("epoch").unwrap() as u64, 2);
    assert!(stubs.iter().all(|s| s.epoch() == 2), "torn epoch after onboard");

    // a client-requested dry_run runs only the gate: no epoch moves
    let dry = send(&addr, r#"{"op":"onboard","anchor":"g4dn","target":"p2","dry_run":true}"#);
    assert_eq!(dry.get("ok").and_then(Json::as_bool), Some(true), "{dry:?}");
    assert!(stubs.iter().all(|s| s.epoch() == 2), "dry_run must not publish");

    // reload publishes fleet-wide through the same two-phase path
    let rl = send(&addr, r#"{"op":"reload"}"#);
    assert_eq!(rl.get("ok").and_then(Json::as_bool), Some(true), "{rl:?}");
    assert!(stubs.iter().all(|s| s.epoch() == 3), "torn epoch after reload");

    // one node's validation gate rejects: the publish aborts in phase 1,
    // the report names the rejecting node, and NO node's epoch moves
    stubs[1].set_reject_dry_run(true);
    let rej = send(&addr, r#"{"op":"onboard","anchor":"g4dn","target":"p2"}"#);
    assert_eq!(rej.get("ok").and_then(Json::as_bool), Some(false), "{rej:?}");
    assert_eq!(rej.req_str("kind").unwrap(), "validation_failed");
    let nodes = rej.get("nodes").and_then(Json::as_arr).unwrap();
    assert_eq!(nodes.len(), 3, "one report per node: {rej:?}");
    let rejected: Vec<&str> = nodes
        .iter()
        .filter(|n| n.get("ok").and_then(Json::as_bool) == Some(false))
        .map(|n| n.req_str("addr").unwrap())
        .collect();
    let reject_addr = stubs[1].addr();
    assert_eq!(rejected, vec![reject_addr.as_str()]);
    assert!(stubs.iter().all(|s| s.epoch() == 3), "a rejected publish must not move any epoch");
    stubs[1].set_reject_dry_run(false);

    // worst case: the gate passes but one node's real publish fails —
    // the divergence is REPORTED per node, never silently absorbed
    stubs[1].set_reject_publish(true);
    let div = send(&addr, r#"{"op":"reload"}"#);
    assert_eq!(div.get("ok").and_then(Json::as_bool), Some(false), "{div:?}");
    assert_eq!(div.req_str("kind").unwrap(), "epoch_divergence");
    let nodes = div.get("nodes").and_then(Json::as_arr).unwrap();
    assert_eq!(
        nodes
            .iter()
            .filter(|n| n.get("ok").and_then(Json::as_bool) == Some(false))
            .count(),
        1,
        "{div:?}"
    );
    handle.stop();
}

#[test]
fn cluster_stats_snapshots_are_never_torn_under_load() {
    let (stubs, handle, addr) = boot(2, 50);
    let pairs = shard_pairs();

    // four client threads hammer predicts across every shard…
    let writers: Vec<_> = (0..4)
        .map(|w| {
            let addr = addr.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                for (i, (a, t)) in pairs.iter().cycle().take(60).enumerate() {
                    let resp = send(&addr, &predict_line(a, t));
                    assert_eq!(
                        resp.get("ok").and_then(Json::as_bool),
                        Some(true),
                        "writer {w} request {i}: {resp:?}"
                    );
                }
            })
        })
        .collect();

    // …while every concurrent snapshot must satisfy the derived
    // invariants: they are computed under ONE lock acquisition, so no
    // interleaving may ever expose a torn view
    for _ in 0..200 {
        let st = cluster_stats(&addr);
        let backends = st.get("backends").and_then(Json::as_arr).unwrap();
        let sum: u64 = backends
            .iter()
            .map(|b| b.req_f64("requests").unwrap() as u64)
            .sum();
        let forwarded = st.req_f64("forwarded").unwrap() as u64;
        assert_eq!(forwarded, sum, "torn snapshot: forwarded != Σ backend requests: {st:?}");
        let healthy = backends
            .iter()
            .filter(|b| b.get("healthy").and_then(Json::as_bool) == Some(true))
            .count();
        assert_eq!(st.req_f64("healthy_backends").unwrap() as usize, healthy, "{st:?}");
    }
    for w in writers {
        w.join().unwrap();
    }

    let st = cluster_stats(&addr);
    assert!(st.req_f64("forwarded").unwrap() as u64 >= 240, "{st:?}");
    assert_eq!(st.req_f64("no_backend").unwrap() as u64, 0, "{st:?}");
    assert!(stubs.iter().all(|s| s.predicts() > 0));
    handle.stop();
}

#[test]
fn cluster_router_rejects_an_empty_backend_list() {
    assert!(serve_cluster(RouteOptions {
        addr: "127.0.0.1:0".into(),
        backends: Vec::new(),
        ..RouteOptions::default()
    })
    .is_err());
}
