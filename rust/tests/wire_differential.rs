//! Differential fuzz: the streaming wire decoder (`Request::parse`) and
//! the DOM reference decoder (`Request::parse_dom`) must agree on every
//! protocol example line AND on seeded random mutations of them — same
//! parsed request on success, same error kind *and message* (byte
//! offsets included) on rejection. This is what licenses serving traffic
//! through the DOM-free path while the DOM stays the reference.

use repro::coordinator::Request;
use repro::util::Rng64;

/// Canonical wire examples: one (or more) per op, plus edge shapes —
/// escaped keys, duplicate fields, whitespace, wrong-typed payloads.
fn base_lines() -> Vec<String> {
    let mut lines: Vec<String> = [
        r#"{"op":"health"}"#,
        r#"{"op":"stats"}"#,
        r#"{"op":"instances"}"#,
        r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":123.4,"profile":{"Conv2D":286.0,"Relu":26.0}}"#,
        r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1.5,"profile":{}}"#,
        "{\"\\u006fp\":\"predict\",\"anchor\":\"g4dn\",\"target\":\"p3\",\"anchor_latency_ms\":1.5,\"profile\":{\"a\\tb\":1,\"a\\tb\":2,\"B\":3.5}}",
        r#" { "op" : "health" , "extra" : [ {"deep": [1, "x", null]} , true ] } "#,
        r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0,"t_max":900.5}"#,
        r#"{"op":"predict_pixel_size","instance":"ac1","pixels":128,"t_min":10.25,"t_max":90.75}"#,
        r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,"profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,"gpu_counts":[1,2],"include_spot":true,"top_k":8}"#,
        r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,"profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,"targets":["p3","g4dn"],"batches":[16,64,256],"pixel_sizes":[64],"profile_pmin":{"Conv2D":40.0},"anchor_lat_pmin":50.0,"profile_pmax":{"Conv2D":1200.0},"anchor_lat_pmax":1500.0}"#,
        r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,"profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,"objective":"cheapest","deadline_hours":4.0,"dataset_images":50000,"epochs":10}"#,
        r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,"profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,"objective":"fastest","budget_usd":12.5,"dataset_images":1000}"#,
        r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,"profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,"objective":"max_epochs","deadline_hours":2.0,"dataset_images":1000}"#,
        // registry ops (live model hot-reload / online onboarding)
        r#"{"op":"reload"}"#,
        r#"{"op":"onboard"}"#,
        r#"{"op":"onboard","anchor":"g4dn","target":"g5"}"#,
        r#"{"op":"ingest","anchor":"g4dn","target":"g5","model":"VGG16","batch":32,"pixels":64,"profile":{"Conv2D":80.5,"Relu":8.25},"anchor_latency_ms":120.5,"target_latency_ms":60.25}"#,
        // malformed on purpose: both decoders must reject identically
        "not json",
        "{}",
        r#"{"op":42}"#,
        "[1,2,3]",
        r#""health""#,
        "12 34",
        r#"{"op":"nope"}"#,
        r#"{"op":"predict","anchor":"zzz","target":"p3","anchor_latency_ms":1,"profile":{}}"#,
        r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1,"profile":{"Conv2D":"x"}}"#,
        r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1,"profile":{"a":1e400,"b":"x"}}"#,
        r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"batches":[16.9],"gpu_counts":[1,"two"],"top_k":-1}"#,
        r#"{"op":"ingest","anchor":"g4dn","target":"g4dn","model":"VGG16","batch":32,"pixels":64,"profile":{"Conv2D":1},"anchor_latency_ms":10,"target_latency_ms":5}"#,
        r#"{"op":"ingest","anchor":"g4dn","target":"g5","model":"NotANet","batch":0,"pixels":64,"profile":{"Conv2D":1},"anchor_latency_ms":10,"target_latency_ms":5}"#,
        r#"{"op":"onboard","anchor":"g4dn"}"#,
    ]
    .into_iter()
    .map(String::from)
    .collect();
    // a line with every axis list populated near its caps
    let batches: Vec<String> = (16..80).map(|b| b.to_string()).collect();
    lines.push(format!(
        r#"{{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{{"Conv2D":1}},"anchor_lat_bmin":5,"profile_bmax":{{"Conv2D":2}},"anchor_lat_bmax":10,"batches":[{}]}}"#,
        batches.join(",")
    ));
    lines
}

/// Both decoders on one line; panic on any divergence.
fn check_agreement(line: &str) {
    let stream = Request::parse(line);
    let dom = Request::parse_dom(line);
    match (stream, dom) {
        (Ok(a), Ok(b)) => assert_eq!(a, b, "request divergence on {line:?}"),
        (Err(a), Err(b)) => {
            assert_eq!(a.kind(), b.kind(), "error-kind divergence on {line:?}");
            assert_eq!(
                a.to_string(),
                b.to_string(),
                "error-message divergence on {line:?}"
            );
        }
        (a, b) => panic!(
            "accept/reject divergence on {line:?}: streaming={:?} dom={:?}",
            a.map(|_| "ok").map_err(|e| e.kind()),
            b.map(|_| "ok").map_err(|e| e.kind()),
        ),
    }
}

#[test]
fn decoders_agree_on_every_example_line() {
    for line in base_lines() {
        check_agreement(&line);
    }
}

/// Seeded mutation fuzz: byte substitutions, insertions, deletions, and
/// targeted token splices over every base line. Mutations that break
/// UTF-8 are skipped (the server rejects those before parsing).
#[test]
fn decoders_agree_on_seeded_mutations() {
    let bases = base_lines();
    let mut rng = Rng64::new(0xD1FF);
    // printable-ish substitution alphabet plus JSON-structural bytes
    let alphabet: &[u8] = b"{}[]\",:.eE+-0123456789 \\abcdxyz\t\nu";
    let splices = [
        "1e400", "-0.0", "null", "true", "\"\"", "NaN", "1e-7", "9e99",
        "{\"a\":1}", "[1]", "\\u0041", "\\ud800", "0x1", "01", "1.", ".5",
    ];
    let mut checked = 0usize;
    for base in &bases {
        for _ in 0..160 {
            let mut bytes = base.clone().into_bytes();
            match rng.below(4) {
                0 => {
                    // substitute a byte
                    let i = rng.below(bytes.len());
                    bytes[i] = alphabet[rng.below(alphabet.len())];
                }
                1 => {
                    // delete a byte
                    let i = rng.below(bytes.len());
                    bytes.remove(i);
                }
                2 => {
                    // insert a byte
                    let i = rng.below(bytes.len() + 1);
                    bytes.insert(i, alphabet[rng.below(alphabet.len())]);
                }
                _ => {
                    // splice a token at a random position
                    let i = rng.below(bytes.len() + 1);
                    let tok = splices[rng.below(splices.len())];
                    bytes.splice(i..i, tok.bytes());
                }
            }
            if let Ok(mutated) = String::from_utf8(bytes) {
                check_agreement(&mutated);
                checked += 1;
            }
        }
    }
    assert!(checked > 2_000, "mutation corpus too small: {checked}");
}
