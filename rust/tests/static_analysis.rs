//! Tier-1 gate for the invariant linter (`repro lint`).
//!
//! Two halves:
//!
//! 1. **The repo lints clean** — `analysis::run` over this very
//!    checkout must produce zero hard findings, which is exactly what
//!    `repro lint` enforces in CI. A regression anywhere (a stray
//!    `format!` on the wire path, a bare `unsafe`, doc drift) fails
//!    `cargo test` before it fails the CI gate.
//! 2. **The linter itself works** — fixture sources with seeded
//!    violations must fire each rule at the exact file:line, allowlist
//!    annotations must silence them, and forbidden tokens inside
//!    string literals/comments must not trip anything.

use repro::analysis::docsync::{self, CodeInventory};
use repro::analysis::rules::{
    self, check_file, Finding, RULE_ALLOC, RULE_ANNOTATION, RULE_BLOCK, RULE_DOC_DRIFT,
    RULE_ORDERING, RULE_UNSAFE, RULE_UNWRAP,
};
use std::path::Path;

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR is <repo>/rust; the linter wants the repo root
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
}

fn lint(path: &str, src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    check_file(path, src, &mut findings);
    findings
}

fn ids(findings: &[Finding]) -> Vec<(&'static str, usize)> {
    findings.iter().map(|f| (f.rule, f.line)).collect()
}

// -------------------------------------------------------------------
// 1. the repo itself
// -------------------------------------------------------------------

#[test]
fn repository_lints_clean() {
    let report = repro::analysis::run(repo_root()).expect("lint run");
    let hard: Vec<String> = report
        .findings
        .iter()
        .filter(|f| !f.advisory)
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        hard.is_empty(),
        "repo must lint clean (this is the `repro lint` CI gate):\n{}",
        hard.join("\n")
    );
    // sanity: the scan actually covered the tree
    assert!(
        report.files.iter().any(|f| f == "src/util/json_stream.rs"),
        "wire-hot module missing from scan: {:?}",
        report.files
    );
    assert!(report.files.len() > 30, "suspiciously few files scanned");
    // every allowlisted site in the audit carries a reason
    for a in &report.allowances {
        assert!(
            !a.reason.trim().is_empty(),
            "allowance without a reason at {}:{} ({})",
            a.file,
            a.line,
            a.rule
        );
    }
}

#[test]
fn repository_advisory_findings_are_unwrap_only() {
    let report = repro::analysis::run(repo_root()).expect("lint run");
    for f in report.findings.iter().filter(|f| f.advisory) {
        assert_eq!(
            f.rule, RULE_UNWRAP,
            "only unwrap-in-server may be advisory: {f:?}"
        );
    }
}

// -------------------------------------------------------------------
// 2. seeded violations fire with exact rule id + line
// -------------------------------------------------------------------

#[test]
fn seeded_alloc_violation_fires_at_exact_line() {
    let src = "fn hot(w: &mut W) {\n    w.push(1);\n    let s = format!(\"{}\", 2);\n}\n";
    let f = lint("src/util/json_stream.rs", src);
    assert_eq!(ids(&f), vec![(RULE_ALLOC, 3)], "{f:?}");
    assert!(f[0].message.contains("format!"));
    // identical source in a non-hot file: silent
    assert!(lint("src/ml/forest.rs", src).is_empty());
}

#[test]
fn seeded_blocking_violation_fires_in_reactor_only() {
    let src = "fn f(rx: &std::sync::mpsc::Receiver<u8>) {\n    let v = rx.recv();\n}\n";
    let f = lint("src/coordinator/reactor.rs", src);
    assert_eq!(ids(&f), vec![(RULE_BLOCK, 2)], "{f:?}");
    assert!(lint("src/coordinator/server.rs", src).is_empty());
}

#[test]
fn seeded_bare_unsafe_fires_everywhere_even_tests() {
    let src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        unsafe { x() };\n    }\n}\n";
    let f = lint("src/util/poll.rs", src);
    assert_eq!(ids(&f), vec![(RULE_UNSAFE, 4)], "cfg(test) is not exempt: {f:?}");
}

#[test]
fn seeded_relaxed_without_justification_fires() {
    let src = "fn f(c: &std::sync::atomic::AtomicUsize) {\n    c.store(0, std::sync::atomic::Ordering::Relaxed);\n}\n";
    let f = lint("src/obs/mod.rs", src);
    assert_eq!(ids(&f), vec![(RULE_ORDERING, 2)], "{f:?}");
    // tests/benches are out of scope for the ordering rule
    assert!(lint("tests/wire_alloc.rs", src).is_empty());
}

#[test]
fn seeded_unwrap_is_advisory_with_lock_poison_builtin() {
    let src = "fn f(m: &std::sync::Mutex<u8>, r: Result<u8, ()>) {\n    let a = m.lock().unwrap();\n    let b = r.unwrap();\n    let c = r.expect(\"boom\");\n}\n";
    let f = lint("src/coordinator/dispatch.rs", src);
    assert_eq!(ids(&f), vec![(RULE_UNWRAP, 3), (RULE_UNWRAP, 4)], "{f:?}");
    assert!(f.iter().all(|x| x.advisory), "unwrap rule must stay advisory");
}

// -------------------------------------------------------------------
// 3. allowlist annotations + false positives
// -------------------------------------------------------------------

#[test]
fn allow_annotations_silence_and_are_audited() {
    let src = "\
// lint: allow(hot-path-alloc): one-time connection setup
fn cold() { let v = Vec::new(); }
fn hot() { let s = String::new(); } // lint: allow(hot-path-alloc): error path
// lint: allow(reactor-blocking-call) begin: startup only
fn boot(m: &std::sync::Mutex<u8>) { let g = m.lock(); }
// lint: allow(reactor-blocking-call) end
";
    let mut findings = Vec::new();
    let ctx = check_file("src/coordinator/reactor.rs", src, &mut findings);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(ctx.allowances.len(), 3);
    assert!(ctx.allowances.iter().any(|a| a.reason.contains("startup only")));
}

#[test]
fn unknown_rule_and_unbalanced_region_are_hard_findings() {
    let f = lint("src/x.rs", "// lint: allow(not-a-rule): hm\nfn f() {}\n");
    assert_eq!(ids(&f), vec![(RULE_ANNOTATION, 1)]);
    let f = lint("src/x.rs", "// lint: allow(hot-path-alloc) begin\nfn f() {}\n");
    assert_eq!(ids(&f), vec![(RULE_ANNOTATION, 1)]);
    assert!(!f[0].advisory);
}

#[test]
fn tokens_inside_strings_and_comments_never_fire() {
    let src = r##"
fn doc() -> &'static str {
    // a comment may say format! or Vec::new or .lock() or unsafe freely
    /* even Ordering::Relaxed in a block comment */
    "format!(vec![Box::new(x.lock().unwrap())]) unsafe Relaxed"
}
fn raw() -> &'static str {
    r#"String::from(".to_string(")"#
}
"##;
    let f = lint("src/coordinator/reactor.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn word_boundaries_prevent_identifier_false_positives() {
    // `MyVec::new_unsafe_relaxed` must not match Vec::new / unsafe / Relaxed
    let src = "fn f() { let x = NotRelaxed::unsafe_marker(); }\n";
    let f = lint("src/obs/mod.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

// -------------------------------------------------------------------
// 4. doc-drift fixtures
// -------------------------------------------------------------------

fn fixture_inventory() -> CodeInventory {
    let mut inv = CodeInventory::default();
    inv.ops.insert("health".into());
    inv.error_kinds.insert("bad_request".into());
    inv.stats_keys.insert("requests".into());
    inv.cluster_stats_keys.insert("forwarded".into());
    inv.gauges.insert("depth".into());
    inv.stages.insert("parse".into());
    inv.metrics_keys.insert("gauges".into());
    inv
}

const CLEAN_DOC: &str = "\
# Protocol

## Ops

| op | purpose |
|---|---|
| [`health`](#health) | liveness |

### health

x

### stats

```json
{\"op\":\"stats\"}
```
```json
{\"requests\":1}
```

### cluster_stats

```json
{\"op\":\"cluster_stats\"}
```
```json
{\"forwarded\":2}
```

### metrics

gauges:

```json
{\"gauges\":{\"depth\":3}}
```

stages: `parse`.

## Error kinds

| kind | meaning |
|---|---|
| `bad_request` | malformed |
";

#[test]
fn doc_drift_clean_fixture_passes() {
    let mut findings = Vec::new();
    docsync::check_doc(&fixture_inventory(), CLEAN_DOC, "docs/PROTOCOL.md", &mut findings);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn doc_drift_detects_missing_and_stale_entries() {
    let mut inv = fixture_inventory();
    inv.ops.insert("reload".into()); // in code, absent from doc
    let doc = CLEAN_DOC.replace("| `bad_request` | malformed |", "| `gone_kind` | stale |");
    let mut findings = Vec::new();
    docsync::check_doc(&inv, &doc, "docs/PROTOCOL.md", &mut findings);
    assert!(findings.iter().all(|f| f.rule == RULE_DOC_DRIFT && !f.advisory));
    assert!(
        findings.iter().any(|f| f.message.contains("`reload`")),
        "missing op undetected: {findings:?}"
    );
    assert!(
        findings.iter().any(|f| f.message.contains("`gone_kind`")),
        "stale kind undetected: {findings:?}"
    );
    assert!(
        findings.iter().any(|f| f.message.contains("`bad_request`")),
        "removed kind undetected: {findings:?}"
    );
    // findings anchor to the doc's section heading lines
    let ops_heading = 1 + CLEAN_DOC.lines().position(|l| l == "## Ops").unwrap();
    assert!(findings
        .iter()
        .any(|f| f.message.contains("`reload`") && f.line == ops_heading));
}

#[test]
fn doc_drift_extraction_skips_test_code_and_non_literals() {
    let src = "\
fn parse(op: &str) -> Op {
    match op {
        \"health\" => Op::Health,
        _ => panic!(),
    }
}
fn route(e: E) -> Response {
    Response::err_kind(e.kind(), format!(\"x\"))
}
#[cfg(test)]
mod tests {
    fn t() {
        let _ = match \"fake_op\" {
            \"fake_op\" => Op::Health,
            _ => panic!(),
        };
    }
}
";
    let mut findings = Vec::new();
    let ctx = check_file("src/coordinator/protocol.rs", src, &mut findings);
    let in_test = |l: usize| ctx.in_test(l);
    let ops = docsync::ops_in_code(&ctx.scan, &in_test);
    assert_eq!(ops.len(), 1);
    assert!(ops.contains("health"), "{ops:?}");
    let mut kinds = std::collections::BTreeSet::new();
    docsync::error_kinds_in_code(&ctx.scan, &in_test, &mut kinds);
    assert!(kinds.is_empty(), "e.kind() is not a literal: {kinds:?}");
}

// -------------------------------------------------------------------
// 5. rule catalogue stays in sync with the docs
// -------------------------------------------------------------------

#[test]
fn every_rule_id_is_documented_in_analysis_md() {
    let doc = std::fs::read_to_string(repo_root().join("docs/ANALYSIS.md"))
        .expect("docs/ANALYSIS.md exists");
    for rule in rules::ALL_RULES {
        assert!(
            doc.contains(&format!("`{rule}`")),
            "rule `{rule}` missing from docs/ANALYSIS.md"
        );
    }
}
