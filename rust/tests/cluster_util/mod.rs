//! Deterministic cluster test harness: stub `repro serve` backends.
//!
//! A [`StubBackend`] is a real `TcpListener` on an ephemeral port
//! speaking just enough of the line protocol (`docs/PROTOCOL.md`) for
//! the route tier to treat it as a healthy `repro serve` node: `stats`
//! answers with `ok` + `registry_epoch` (what the health prober
//! requires), `predict` answers with `latency_ms`/`member` plus a
//! `served_by` marker so tests can assert *which* backend the router
//! picked, and `ingest`/`onboard`/`reload` implement the epoch
//! machinery (including the `dry_run` validation gate) over plain
//! atomics — no runtime, no model artifacts, no nondeterminism.
//!
//! `kill()` simulates a dead node without releasing the port (no
//! TIME_WAIT rebind races): the listener keeps accepting but every
//! connection — pooled ones included — is dropped without a reply,
//! which is exactly what a router's peer client observes when a node
//! dies behind a live address.

#![allow(dead_code)]

use repro::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Shared mutable state of one stub node.
///
/// All counters are independent test observables (never read together
/// as an invariant), so plain relaxed atomics are fine here.
struct Inner {
    addr: String,
    epoch: AtomicU64,
    staged: AtomicU64,
    requests: AtomicU64,
    predicts: AtomicU64,
    hints: AtomicU64,
    ingests: AtomicU64,
    /// Dead-node simulation: accept, then drop without answering.
    down: AtomicBool,
    /// Make the `dry_run` validation gate (phase 1 of a fleet publish)
    /// reject with `validation_failed`.
    reject_dry_run: AtomicBool,
    /// Make the *real* publish (phase 2) fail after the gate passed —
    /// the torn-epoch scenario the router must surface, never hide.
    reject_publish: AtomicBool,
    stop: AtomicBool,
}

/// One stub backend node; see the module docs.
pub struct StubBackend {
    inner: Arc<Inner>,
}

impl StubBackend {
    /// Bind an ephemeral port and start serving (epoch starts at 1).
    pub fn start() -> StubBackend {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let inner = Arc::new(Inner {
            addr,
            epoch: AtomicU64::new(1),
            staged: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            predicts: AtomicU64::new(0),
            hints: AtomicU64::new(0),
            ingests: AtomicU64::new(0),
            down: AtomicBool::new(false),
            reject_dry_run: AtomicBool::new(false),
            reject_publish: AtomicBool::new(false),
            stop: AtomicBool::new(false),
        });
        {
            let inner = inner.clone();
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if inner.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    if inner.down.load(Ordering::Relaxed) {
                        drop(stream); // dead node: connect succeeds, then EOF
                        continue;
                    }
                    let inner = inner.clone();
                    std::thread::spawn(move || serve_conn(&inner, stream));
                }
            });
        }
        StubBackend { inner }
    }

    pub fn addr(&self) -> String {
        self.inner.addr.clone()
    }

    /// Simulate the node dying: every connection (old or new) goes
    /// dead-silent, but the address stays bound.
    pub fn kill(&self) {
        self.inner.down.store(true, Ordering::Relaxed);
    }

    /// Bring the killed node back on the same address.
    pub fn revive(&self) {
        self.inner.down.store(false, Ordering::Relaxed);
    }

    pub fn set_reject_dry_run(&self, reject: bool) {
        self.inner.reject_dry_run.store(reject, Ordering::Relaxed);
    }

    pub fn set_reject_publish(&self, reject: bool) {
        self.inner.reject_publish.store(reject, Ordering::Relaxed);
    }

    pub fn epoch(&self) -> u64 {
        self.inner.epoch.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.inner.requests.load(Ordering::Relaxed)
    }

    pub fn predicts(&self) -> u64 {
        self.inner.predicts.load(Ordering::Relaxed)
    }

    pub fn hints(&self) -> u64 {
        self.inner.hints.load(Ordering::Relaxed)
    }

    pub fn ingests(&self) -> u64 {
        self.inner.ingests.load(Ordering::Relaxed)
    }

    /// Stop accepting new connections (handlers drain naturally).
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.inner.addr);
    }
}

impl Drop for StubBackend {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_conn(inner: &Inner, stream: TcpStream) {
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        // mid-connection kill: pooled router connections go silent too
        if inner.down.load(Ordering::Relaxed) {
            return;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = handle(inner, trimmed);
        if out.write_all(reply.as_bytes()).is_err() || out.write_all(b"\n").is_err() {
            return;
        }
    }
}

fn err(kind: &str, msg: &str) -> String {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(false));
    o.set("kind", Json::Str(kind.into()));
    o.set("error", Json::Str(msg.into()));
    o.to_string()
}

fn handle(inner: &Inner, line: &str) -> String {
    inner.requests.fetch_add(1, Ordering::Relaxed);
    let Ok(j) = Json::parse(line) else {
        return err("bad_request", "stub could not parse the line");
    };
    let op = j.req_str("op").unwrap_or("");
    let dry_run = j.get("dry_run").and_then(Json::as_bool) == Some(true);
    match op {
        "health" => r#"{"ok":true}"#.to_string(),
        "stats" => {
            // the minimum the health prober needs: ok + registry_epoch
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("registry_epoch", Json::Num(inner.epoch.load(Ordering::Relaxed) as f64));
            o.set("requests", Json::Num(inner.requests.load(Ordering::Relaxed) as f64));
            o.to_string()
        }
        "predict" | "predict_batch_size" | "predict_pixel_size" => {
            inner.predicts.fetch_add(1, Ordering::Relaxed);
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("latency_ms", Json::Num(7.5));
            o.set("member", Json::Str("Linear".into()));
            // not a wire field — the harness marker tests shard-match on
            o.set("served_by", Json::Str(inner.addr.clone()));
            o.to_string()
        }
        "hint" => {
            inner.hints.fetch_add(1, Ordering::Relaxed);
            let applied = j.get("epoch").and_then(Json::as_f64).map(|e| e as u64)
                == Some(inner.epoch.load(Ordering::Relaxed));
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("applied", Json::Bool(applied));
            o.to_string()
        }
        "ingest" => {
            inner.ingests.fetch_add(1, Ordering::Relaxed);
            let staged = inner.staged.fetch_add(1, Ordering::Relaxed) + 1;
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("staged", Json::Num(staged as f64));
            o.to_string()
        }
        "onboard" | "reload" => {
            if dry_run {
                if inner.reject_dry_run.load(Ordering::Relaxed) {
                    return err("validation_failed", "stub validation gate rejected the candidate");
                }
                let mut o = Json::obj();
                o.set("ok", Json::Bool(true));
                o.set("epoch", Json::Num(inner.epoch.load(Ordering::Relaxed) as f64));
                o.set("staged", Json::Num(inner.staged.load(Ordering::Relaxed) as f64));
                return o.to_string();
            }
            if inner.reject_publish.load(Ordering::Relaxed) {
                return err("internal_error", "stub publish failed after the gate passed");
            }
            let epoch = inner.epoch.fetch_add(1, Ordering::Relaxed) + 1;
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("epoch", Json::Num(epoch as f64));
            o.set("staged", Json::Num(inner.staged.load(Ordering::Relaxed) as f64));
            o.to_string()
        }
        other => err("unknown_op", &format!("stub does not serve `{other}`")),
    }
}

/// One-line request/reply round trip against any line-protocol server.
pub fn send(addr: &str, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap()
}

/// A valid wire `predict` line for the given shard pair.
pub fn predict_line(anchor: &str, target: &str) -> String {
    format!(
        r#"{{"op":"predict","anchor":"{anchor}","target":"{target}","anchor_latency_ms":42.5,"profile":{{"Conv2D":286,"Relu":26}}}}"#
    )
}

/// A valid wire `ingest` line for the given shard pair.
pub fn ingest_line(anchor: &str, target: &str) -> String {
    format!(
        r#"{{"op":"ingest","anchor":"{anchor}","target":"{target}","model":"VGG16","batch":32,"pixels":64,"profile":{{"Conv2D":1}},"anchor_latency_ms":10,"target_latency_ms":5}}"#
    )
}

/// Every ordered (anchor, target) pair of distinct core instances —
/// enough shard-key diversity to hit all backends of a small ring.
pub fn shard_pairs() -> Vec<(&'static str, &'static str)> {
    let names = ["g3s", "g4dn", "p2", "p3", "g5", "ac1"];
    let mut pairs = Vec::new();
    for a in names {
        for t in names {
            if a != t {
                pairs.push((a, t));
            }
        }
    }
    pairs
}
