//! Failpoint-driven chaos suite: every fault hook compiled into the
//! serving stack (`docs/RESILIENCE.md` has the catalogue) is armed here
//! and driven end to end — crash at each step of the crash-safe model
//! save, a torn staging-log tail under live `ingest`/`onboard`, an
//! engine replica panicking mid-request, reactor write stalls and torn
//! socket writes under drain, and a model-dir watcher whose reload tick
//! faults mid-watch. The invariants under test: the serving directory
//! is never left unloadable, no client reply is ever lost (worst case
//! it degrades to a structured error), and the registry epoch only
//! moves forward.
//!
//! The failpoint registry is process-global, so this binary must run
//! single-threaded: `ci/chaos_check.sh` passes `--test-threads=1`, and
//! every test name carries the `chaos_` prefix so the general
//! `cargo test` sweep in `ci/check.sh` can `--skip chaos_`.

mod cluster_util;

use repro::coordinator::cluster::Ring;
use repro::coordinator::{self, PoolOptions, RouteOptions, ServeOptions};
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::predictor::{sweep_orphaned_saves, Profet, TrainOptions};
use repro::runtime;
use repro::util::failpoint::{self, Action};
use repro::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Train once per test binary, save to a chaos-private temp dir (never
/// shared with `server_integration` — both binaries may run in one CI
/// sweep). `None` when the runtime backend is unavailable.
fn model_dir() -> Option<&'static std::path::PathBuf> {
    static DIR: OnceLock<Option<std::path::PathBuf>> = OnceLock::new();
    DIR.get_or_init(|| {
        let rt = match runtime::load_default() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping chaos tests: runtime unavailable: {e:#}");
                return None;
            }
        };
        let corpus = Corpus::generate(&[Instance::G4dn, Instance::P3]);
        let (train_idx, _) = corpus.split_random(0.1, 11);
        let opts = TrainOptions {
            anchors: vec![Instance::G4dn],
            targets: vec![Instance::P3],
            clustering: true,
            poly_order: 2,
            n_trees: 15,
            dnn_epochs: 8,
            seed: 99,
        };
        let profet = Profet::train(&rt, &corpus, &train_idx, &opts).unwrap();
        let dir = std::env::temp_dir().join("repro_chaos_models");
        std::fs::remove_dir_all(&dir).ok();
        profet.save(&dir).unwrap();
        Some(dir)
    })
    .as_ref()
}

/// Copy the shared trained dir into a test-private scratch dir — chaos
/// tests corrupt, overwrite, and hot-swap their model directory.
fn copy_model_dir(tag: &str) -> std::path::PathBuf {
    let src = model_dir().expect("caller checked");
    let dst = std::env::temp_dir().join(format!("repro_chaos_models_{tag}"));
    std::fs::remove_dir_all(&dst).ok();
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let path = entry.unwrap().path();
        if path.is_file() {
            std::fs::copy(&path, dst.join(path.file_name().unwrap())).unwrap();
        }
    }
    dst
}

fn send(addr: std::net::SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap()
}

fn sample_profile_line() -> String {
    let w = repro::sim::Workload::new(repro::models::ModelId::ResNet18, 32, 64);
    let run = repro::sim::run_workload(&w, Instance::G4dn).unwrap();
    let mut profile = Json::obj();
    for (k, v) in run.profile.aggregated() {
        profile.set(&k, Json::Num(v));
    }
    let mut req = Json::obj();
    req.set("op", Json::Str("predict".into()));
    req.set("anchor", Json::Str("g4dn".into()));
    req.set("target", Json::Str("p3".into()));
    req.set("anchor_latency_ms", Json::Num(run.latency_ms));
    req.set("profile", profile);
    req.to_string()
}

/// Cache-bust a predict line by whole quantization buckets so each
/// variant takes the engine-lane miss path (cf. `server_integration`).
fn bust_predict_line(line: &str, bust: usize) -> String {
    let mut req = Json::parse(line).unwrap();
    let v = req.req_f64("anchor_latency_ms").unwrap();
    req.set("anchor_latency_ms", Json::Num(v * (1.0 + bust as f64 * 1e-3)));
    req.to_string()
}

/// Disarm everything on entry and exit (even when a test panics): the
/// failpoint registry is process-global and outlives each test.
struct FpGuard;

impl Drop for FpGuard {
    fn drop(&mut self) {
        failpoint::clear_all();
    }
}

fn fp_guard() -> FpGuard {
    failpoint::clear_all();
    FpGuard
}

fn assert_ok(resp: &Json) {
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
}

fn assert_err_kind(resp: &Json, kind: &str) {
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{resp:?}");
    assert_eq!(resp.req_str("kind").unwrap(), kind, "{resp:?}");
}

/// Tentpole (b)+(e): crash the model save at every step — staged write
/// (error and torn-file flavors), component commit, manifest finalize,
/// each as both a clean error and a panic — and prove the serving
/// directory loads cleanly after every single one. Panicked saves leave
/// staging orphans behind by design; the recovery sweep removes them.
#[test]
fn chaos_save_crash_matrix_leaves_the_serving_dir_loadable() {
    let Some(_) = model_dir() else { return };
    let _fp = fp_guard();
    let dir = copy_model_dir("save_matrix");
    let profet = Profet::load(&dir).unwrap();

    let mut panics = 0;
    for point in ["registry.save.stage", "registry.save.commit", "registry.save.finalize"] {
        for action in [Action::ReturnErr, Action::Panic] {
            failpoint::configure(point, action);
            let result = catch_unwind(AssertUnwindSafe(|| profet.save(&dir)));
            failpoint::clear_all();
            match result {
                Ok(Ok(())) => panic!("save must fail with {point} armed as {action:?}"),
                Ok(Err(_)) => {}
                Err(_) => panics += 1,
            }
            Profet::load(&dir).unwrap_or_else(|e| {
                panic!("serving dir corrupt after {point} {action:?}: {e:#}")
            });
        }
    }
    assert_eq!(panics, 3, "the panic flavor of each point must unwind");

    // a torn staged write stays confined to the temp sibling
    failpoint::configure("registry.save.stage", Action::PartialWrite(10));
    assert!(profet.save(&dir).is_err(), "torn staged write must fail the save");
    failpoint::clear_all();
    Profet::load(&dir).expect("torn staged write must never touch the serving dir");

    // each panicked save abandoned its staging sibling; the sweep (what
    // the registry runs at open and before reload) removes all of them
    let swept = sweep_orphaned_saves(&dir);
    assert!(swept >= 3, "expected the 3 panicked saves' orphans, swept {swept}");
    assert_eq!(sweep_orphaned_saves(&dir), 0, "sweep must converge");

    // fresh-target flavor: a failed finalize publishes nothing at all
    let fresh = std::env::temp_dir().join("repro_chaos_models_fresh_target");
    std::fs::remove_dir_all(&fresh).ok();
    failpoint::configure("registry.save.finalize", Action::ReturnErr);
    assert!(profet.save(&fresh).is_err());
    failpoint::clear_all();
    assert!(!fresh.exists(), "failed fresh-target save must not create the dir");

    // and with everything disarmed the same paths round-trip cleanly
    profet.save(&dir).unwrap();
    profet.save(&fresh).unwrap();
    Profet::load(&dir).unwrap();
    Profet::load(&fresh).unwrap();
    assert_eq!(sweep_orphaned_saves(&dir), 0, "clean saves leave no orphans");
}

/// Tentpole (b)+(e): tear the staging append log mid-record under live
/// `ingest` traffic, then prove replay skips the torn tail and the
/// `onboard` still trains and publishes the new pair.
#[test]
fn chaos_torn_staging_tail_never_fails_the_onboard() {
    let Some(_) = model_dir() else { return };
    let _fp = fp_guard();
    let models = copy_model_dir("torn_staging");
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    let corpus = Corpus::generate(&[Instance::G4dn, Instance::P2]);
    let paired: Vec<&repro::data::Entry> = corpus
        .entries
        .iter()
        .filter(|e| e.runs.contains_key(&Instance::G4dn) && e.runs.contains_key(&Instance::P2))
        .collect();
    assert!(paired.len() >= 33, "{}", paired.len());
    let ingest_line = |e: &repro::data::Entry| {
        let ar = &e.runs[&Instance::G4dn];
        let tr = &e.runs[&Instance::P2];
        let mut req = Json::obj();
        req.set("op", Json::Str("ingest".into()));
        req.set("anchor", Json::Str("g4dn".into()));
        req.set("target", Json::Str("p2".into()));
        req.set("model", Json::Str(e.workload.model.name().into()));
        req.set("batch", Json::Num(e.workload.batch as f64));
        req.set("pixels", Json::Num(e.workload.pixels as f64));
        let mut prof = Json::obj();
        for (k, v) in &ar.profile {
            prof.set(&k.clone(), Json::Num(*v));
        }
        req.set("profile", prof);
        req.set("anchor_latency_ms", Json::Num(ar.latency_ms));
        req.set("target_latency_ms", Json::Num(tr.latency_ms));
        req.to_string()
    };

    // 5 clean records land
    let mut staged = 0;
    for e in paired.iter().take(5) {
        let resp = send(addr, &ingest_line(e));
        assert_ok(&resp);
        staged = resp.req_f64("staged").unwrap() as usize;
    }
    assert_eq!(staged, 5);

    // a crash mid-append tears the 6th record: the client sees a
    // structured failure and the file is left without a trailing newline
    failpoint::configure("registry.staging.append", Action::PartialWrite(25));
    let torn = send(addr, &ingest_line(paired[5]));
    assert_eq!(torn.get("ok").and_then(Json::as_bool), Some(false), "{torn:?}");
    failpoint::clear_all();
    let log = models.join("staging").join("g4dn_p2.jsonl");
    let bytes = std::fs::read(&log).unwrap();
    assert!(!bytes.ends_with(b"\n"), "append must have been torn mid-record");

    // the next append heals the tail; the torn bytes never count again
    for e in paired.iter().skip(6).take(27) {
        let resp = send(addr, &ingest_line(e));
        assert_ok(&resp);
        staged = resp.req_f64("staged").unwrap() as usize;
    }
    assert_eq!(staged, 32, "torn record must not count toward the staged total");

    // onboard trains on the 32 valid records and publishes epoch 2 —
    // the torn tail is skipped, never fatal
    let ob = send(addr, r#"{"op":"onboard","anchor":"g4dn","target":"p2"}"#);
    assert_ok(&ob);
    assert_eq!(ob.req_f64("epoch").unwrap() as u64, 2);
    assert_eq!(ob.req_f64("staged").unwrap() as u64, 32);

    let st = send(addr, r#"{"op":"stats"}"#);
    assert_eq!(st.req_f64("registry_epoch").unwrap() as u64, 2);
    handle.stop();
}

/// Tentpole (c)+(e): a replica that panics mid-request answers a
/// structured `internal_error` instead of wedging the connection, the
/// supervisor respawns it (visible as `lane_restarts` in `stats`), and
/// the very next request is served normally.
#[test]
fn chaos_panicking_replica_answers_internal_error_and_recovers() {
    let Some(models) = model_dir() else { return };
    let _fp = fp_guard();
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();

    // clean cold predict through the engine lane first
    assert_ok(&send(addr, &bust_predict_line(&line, 1)));

    // return-err flavor: the lane consumes the job with a structured error
    failpoint::configure("lane.execute", Action::ReturnErr);
    let e1 = send(addr, &bust_predict_line(&line, 2));
    assert_err_kind(&e1, "internal_error");

    // panic flavor: the replica unwinds mid-request; the reply drop
    // guard still answers — the client is never left hanging
    failpoint::configure("lane.execute", Action::Panic);
    let e2 = send(addr, &bust_predict_line(&line, 3));
    assert_err_kind(&e2, "internal_error");
    assert!(
        e2.req_str("error").unwrap().contains("panicked"),
        "drop-guard reply should say the replica panicked: {e2:?}"
    );
    failpoint::clear_all();

    // the supervisor respawned the replica: the next request works and
    // the restart is surfaced in stats
    assert_ok(&send(addr, &bust_predict_line(&line, 4)));
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let st = send(addr, r#"{"op":"stats"}"#);
        if st.req_f64("lane_restarts").unwrap() >= 1.0 {
            break;
        }
        assert!(Instant::now() < deadline, "lane_restarts never surfaced: {st:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.stop();
}

/// Tentpole (a)+(e): reactor write stalls (`delay`) and torn socket
/// writes (`partial-write`, forcing the backlog/flush path) must never
/// lose or corrupt a reply, and a graceful drain completes while the
/// faults are still armed.
#[test]
fn chaos_reactor_write_faults_do_not_lose_replies() {
    let Some(models) = model_dir() else { return };
    let _fp = fp_guard();
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();
    let baseline = send(addr, &line);
    assert_ok(&baseline);
    let expect_bits = baseline.req_f64("latency_ms").unwrap().to_bits();

    // write stall: every reactor write sleeps, replies still arrive intact
    failpoint::configure("reactor.write", Action::Delay(5));
    failpoint::configure("reactor.flush", Action::Delay(5));
    for _ in 0..5 {
        let warm = send(addr, &line);
        assert_ok(&warm);
        assert_eq!(warm.req_f64("latency_ms").unwrap().to_bits(), expect_bits);
    }

    // torn writes: cap every direct write at 9 bytes so each reply is
    // forced through the backlog, then flushed across many poll cycles
    failpoint::configure("reactor.write", Action::PartialWrite(9));
    failpoint::configure("reactor.flush", Action::Off);
    for _ in 0..3 {
        let warm = send(addr, &line);
        assert_ok(&warm);
        assert_eq!(warm.req_f64("latency_ms").unwrap().to_bits(), expect_bits);
    }

    // harshest combination: torn direct writes AND a torn flush path;
    // a multi-hundred-byte stats reply still arrives whole
    failpoint::configure("reactor.flush", Action::PartialWrite(7));
    for _ in 0..3 {
        let warm = send(addr, &line);
        assert_ok(&warm);
        assert_eq!(warm.req_f64("latency_ms").unwrap().to_bits(), expect_bits);
    }
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("requests").unwrap() >= 12.0, "{st:?}");
    assert!(failpoint::hit_count("reactor.write") >= 10, "write hook must have fired");
    assert!(failpoint::hit_count("reactor.flush") >= 1, "flush hook must have fired");

    // drain under injection: concurrent clients all get their reply,
    // then a graceful stop completes with the faults still armed
    failpoint::configure("reactor.write", Action::Delay(10));
    failpoint::configure("reactor.flush", Action::Delay(10));
    let clients: Vec<_> = (0..4)
        .map(|_| {
            let line = line.clone();
            std::thread::spawn(move || send(addr, &line))
        })
        .collect();
    for c in clients {
        let resp = c.join().unwrap();
        assert_ok(&resp);
        assert_eq!(resp.req_f64("latency_ms").unwrap().to_bits(), expect_bits);
    }
    handle.stop(); // must not hang with delay hooks armed
}

/// Satellite 3 + tentpole (e): while every watcher tick faults, a model
/// directory change is NOT picked up and the old epoch keeps serving;
/// once the fault clears, the watcher converges to the new epoch — and
/// the observed epoch never moves backwards.
#[test]
fn chaos_watcher_tick_faults_keep_the_served_epoch() {
    let Some(_) = model_dir() else { return };
    let _fp = fp_guard();
    let models = copy_model_dir("watch_fault");
    let handle = coordinator::serve_with(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
        &ServeOptions {
            model_dir_watch: Some(Duration::from_millis(50)),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();
    assert_ok(&send(addr, &line));
    let epoch_of = |st: &Json| st.req_f64("registry_epoch").unwrap() as u64;
    assert_eq!(epoch_of(&send(addr, r#"{"op":"stats"}"#)), 1);

    // fault every tick, then change the model dir's fingerprint (size
    // delta — trailing whitespace keeps the JSON valid)
    failpoint::configure("server.watch.tick", Action::ReturnErr);
    let fs_path = models.join("feature_space.json");
    let mut contents = std::fs::read(&fs_path).unwrap();
    contents.push(b'\n');
    std::fs::write(&fs_path, &contents).unwrap();

    // let the watcher tick at least twice while faulted
    let deadline = Instant::now() + Duration::from_secs(10);
    while failpoint::hit_count("server.watch.tick") < 2 {
        assert!(Instant::now() < deadline, "watcher never ticked");
        std::thread::sleep(Duration::from_millis(20));
    }

    // the change was NOT picked up: old epoch, predictions still served
    assert_eq!(epoch_of(&send(addr, r#"{"op":"stats"}"#)), 1);
    assert_ok(&send(addr, &line));

    // clear the fault: the watcher converges to epoch 2, monotonically
    failpoint::clear_all();
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut last = 1;
    loop {
        let epoch = epoch_of(&send(addr, r#"{"op":"stats"}"#));
        assert!(epoch >= last, "epoch must never move backwards: {last} -> {epoch}");
        last = epoch;
        if epoch == 2 {
            break;
        }
        assert!(Instant::now() < deadline, "watcher never reloaded after the fault cleared");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_ok(&send(addr, &line));
    handle.stop();
}

/// Cluster tentpole: partition one backend (per-address
/// `cluster.peer.send.<addr>` failpoint) under open-loop predict load.
/// The route tier must (1) lose zero replies — every request is
/// answered, failing over to the surviving ring owner, (2) surface the
/// ejection in `cluster_stats`, and (3) rejoin the backend once the
/// partition heals, restoring its shard. Runtime-free: the backends are
/// the deterministic stub harness from `tests/cluster_util/`.
#[test]
fn chaos_cluster_partitioned_backend_sheds_no_replies_and_rejoins() {
    let _fp = fp_guard();
    let stubs: Vec<cluster_util::StubBackend> =
        (0..2).map(|_| cluster_util::StubBackend::start()).collect();
    let backends: Vec<String> = stubs.iter().map(|s| s.addr()).collect();
    let handle = coordinator::cluster::serve_cluster(RouteOptions {
        addr: "127.0.0.1:0".into(),
        backends: backends.clone(),
        probe_interval: Duration::from_millis(25),
        fail_threshold: 2,
        call_timeout: Duration::from_millis(500),
    })
    .unwrap();
    let addr = handle.addr().to_string();
    fn cluster_stats(addr: &str) -> Json {
        cluster_util::send(addr, r#"{"op":"cluster_stats"}"#)
    }
    fn wait_for(what: &str, mut done: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !done() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    // two probe rounds: the router knows every backend's epoch
    wait_for("two probe rounds", || stubs.iter().all(|s| s.requests() >= 2));

    let oracle = Ring::new(backends.clone());
    let victim_addr = oracle.backends()[0].clone();
    let (va, vt) = cluster_util::shard_pairs()
        .into_iter()
        .find(|(a, t)| oracle.owner(Ring::shard_key(a, t)) == Some(0))
        .unwrap();

    // open-loop load: a fixed schedule of 200 predicts across every
    // shard; the writer asserts every single reply arrives and is ok
    let writer = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            for (i, (a, t)) in cluster_util::shard_pairs().iter().cycle().take(200).enumerate() {
                let resp = cluster_util::send(&addr, &cluster_util::predict_line(a, t));
                assert_eq!(
                    resp.get("ok").and_then(Json::as_bool),
                    Some(true),
                    "request {i} lost or failed under partition: {resp:?}"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    // partition the shard owner mid-load
    std::thread::sleep(Duration::from_millis(30));
    let fp = format!("cluster.peer.send.{victim_addr}");
    failpoint::configure(&fp, Action::ReturnErr);

    // the prober (same failpoint) ejects it; load keeps flowing
    wait_for("the ejection to surface", || {
        let st = cluster_stats(&addr);
        st.req_f64("healthy_backends").unwrap() as usize == 1
            && st.req_f64("ejections").unwrap() >= 1.0
    });

    // heal the partition: the backend rejoins and its shard comes home
    failpoint::clear(&fp);
    wait_for("the rejoin", || {
        let st = cluster_stats(&addr);
        st.req_f64("healthy_backends").unwrap() as usize == 2
            && st.req_f64("rejoins").unwrap() >= 1.0
    });
    writer.join().expect("no reply may be lost under the partition");
    let resp = cluster_util::send(&addr, &cluster_util::predict_line(va, vt));
    assert_eq!(resp.req_str("served_by").unwrap(), victim_addr);

    let st = cluster_stats(&addr);
    assert!(st.req_f64("retries").unwrap() >= 1.0, "{st:?}");
    assert_eq!(st.req_f64("no_backend").unwrap() as u64, 0, "{st:?}");
    handle.stop();
}

/// Tentpole (d): with a `--default-deadline-ms` budget configured, jobs
/// whose queue wait blew the budget are shed at dequeue with the
/// structured `deadline_exceeded` error — and the job that caused the
/// pile-up still answers normally.
#[test]
fn chaos_queue_wait_past_the_deadline_is_shed_structurally() {
    let Some(models) = model_dir() else { return };
    let _fp = fp_guard();
    let handle = coordinator::serve_with(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
        &ServeOptions {
            pool: PoolOptions {
                predict_lanes: 1, // one lane so the stall serializes the queue
                default_deadline: Some(Duration::from_millis(100)),
                ..PoolOptions::default()
            },
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();

    // the first admitted job stalls 400ms inside the lane (well past the
    // 100ms budget); everything queued behind it expires in the queue
    failpoint::configure("lane.execute", Action::Delay(400));
    let clients: Vec<_> = (0..4)
        .map(|bust| {
            let line = bust_predict_line(&line, 10 + bust);
            let t = std::thread::spawn(move || send(addr, &line));
            std::thread::sleep(Duration::from_millis(5));
            t
        })
        .collect();
    let replies: Vec<Json> = clients.into_iter().map(|c| c.join().unwrap()).collect();
    failpoint::clear_all();

    let ok = replies
        .iter()
        .filter(|r| r.get("ok").and_then(Json::as_bool) == Some(true))
        .count();
    let shed: Vec<&Json> = replies
        .iter()
        .filter(|r| r.get("ok").and_then(Json::as_bool) == Some(false))
        .collect();
    assert_eq!(ok, 1, "exactly the first-admitted job executes: {replies:?}");
    assert_eq!(shed.len(), 3, "{replies:?}");
    for r in &shed {
        assert_eq!(r.req_str("kind").unwrap(), "deadline_exceeded", "{r:?}");
    }

    // with the stall gone, fresh cold predicts are well inside budget
    assert_ok(&send(addr, &bust_predict_line(&line, 20)));
    handle.stop();
}
