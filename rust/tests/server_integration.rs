//! Coordinator integration: boot the full TCP service on an ephemeral
//! port, train + save a model directory, then drive it like a client —
//! including concurrent requests that exercise the dynamic batcher.

use repro::coordinator;
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::predictor::{Profet, TrainOptions};
use repro::runtime;
use repro::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::OnceLock;

/// Train once per test binary, save to a shared temp dir. `None` when the
/// runtime backend is unavailable (offline build with the xla shim).
fn model_dir() -> Option<&'static std::path::PathBuf> {
    static DIR: OnceLock<Option<std::path::PathBuf>> = OnceLock::new();
    DIR.get_or_init(|| {
        let rt = match runtime::load_default() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping server tests: runtime unavailable: {e:#}");
                return None;
            }
        };
        let corpus = Corpus::generate(&[Instance::G4dn, Instance::P3]);
        let (train_idx, _) = corpus.split_random(0.1, 11);
        let opts = TrainOptions {
            anchors: vec![Instance::G4dn],
            targets: vec![Instance::P3],
            clustering: true,
            poly_order: 2,
            n_trees: 15,
            dnn_epochs: 8,
            seed: 99,
        };
        let profet = Profet::train(&rt, &corpus, &train_idx, &opts).unwrap();
        let dir = std::env::temp_dir().join("repro_server_models");
        std::fs::remove_dir_all(&dir).ok();
        profet.save(&dir).unwrap();
        Some(dir)
    })
    .as_ref()
}

fn send(addr: std::net::SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap()
}

fn sample_profile_line() -> String {
    // real-ish aggregated profile: measured on the simulator
    let w = repro::sim::Workload::new(repro::models::ModelId::ResNet18, 32, 64);
    let run = repro::sim::run_workload(&w, Instance::G4dn).unwrap();
    let mut profile = Json::obj();
    for (k, v) in run.profile.aggregated() {
        profile.set(&k, Json::Num(v));
    }
    let mut req = Json::obj();
    req.set("op", Json::Str("predict".into()));
    req.set("anchor", Json::Str("g4dn".into()));
    req.set("target", Json::Str("p3".into()));
    req.set("anchor_latency_ms", Json::Num(run.latency_ms));
    req.set("profile", profile);
    req.to_string()
}

#[test]
fn serves_health_instances_predict_and_errors() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    // health
    let h = send(addr, r#"{"op":"health"}"#);
    assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));

    // instances
    let i = send(addr, r#"{"op":"instances"}"#);
    assert_eq!(i.req_arr("instances").unwrap().len(), 6);

    // predict (end to end through feature space + ensemble + HLO forward)
    let p = send(addr, &sample_profile_line());
    assert_eq!(p.get("ok").and_then(Json::as_bool), Some(true), "{p:?}");
    let lat = p.req_f64("latency_ms").unwrap();
    assert!(lat > 1.0 && lat < 10_000.0, "latency {lat}");
    assert!(p.req_str("member").is_ok());

    // phase-2 batch interpolation
    let b = send(
        addr,
        r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0,"t_max":900.0}"#,
    );
    let v = b.req_f64("latency_ms").unwrap();
    assert!(v > 50.0 && v < 1000.0, "{v}");

    // serving stats reflect the traffic so far
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("requests").unwrap() >= 2.0);
    assert!(st.req_f64("artifact_batches").unwrap() >= 1.0);

    // errors: bad op, unknown pair
    let e = send(addr, r#"{"op":"nope"}"#);
    assert_eq!(e.get("ok").and_then(Json::as_bool), Some(false));
    let e2 = send(
        addr,
        r#"{"op":"predict","anchor":"p2","target":"g3s","anchor_latency_ms":1,"profile":{"Conv2D":1}}"#,
    );
    assert_eq!(e2.get("ok").and_then(Json::as_bool), Some(false));

    handle.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();

    let n = 24;
    let mut joins = Vec::new();
    for _ in 0..n {
        let line = line.clone();
        joins.push(std::thread::spawn(move || send(addr, &line)));
    }
    let mut latencies = Vec::new();
    for j in joins {
        let resp = j.join().unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        latencies.push(resp.req_f64("latency_ms").unwrap());
    }
    // identical request → identical prediction, through any batch grouping
    for l in &latencies {
        assert!((l - latencies[0]).abs() < 1e-6);
    }
    handle.stop();
}
