//! Coordinator integration: boot the full TCP service on an ephemeral
//! port, train + save a model directory, then drive it like a client —
//! including concurrent requests that exercise the dynamic batcher.

use repro::coordinator;
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::predictor::{Profet, TrainOptions};
use repro::runtime;
use repro::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::OnceLock;

/// Train once per test binary, save to a shared temp dir. `None` when the
/// runtime backend is unavailable (offline build with the xla shim).
fn model_dir() -> Option<&'static std::path::PathBuf> {
    static DIR: OnceLock<Option<std::path::PathBuf>> = OnceLock::new();
    DIR.get_or_init(|| {
        let rt = match runtime::load_default() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping server tests: runtime unavailable: {e:#}");
                return None;
            }
        };
        let corpus = Corpus::generate(&[Instance::G4dn, Instance::P3]);
        let (train_idx, _) = corpus.split_random(0.1, 11);
        let opts = TrainOptions {
            anchors: vec![Instance::G4dn],
            targets: vec![Instance::P3],
            clustering: true,
            poly_order: 2,
            n_trees: 15,
            dnn_epochs: 8,
            seed: 99,
        };
        let profet = Profet::train(&rt, &corpus, &train_idx, &opts).unwrap();
        let dir = std::env::temp_dir().join("repro_server_models");
        std::fs::remove_dir_all(&dir).ok();
        profet.save(&dir).unwrap();
        Some(dir)
    })
    .as_ref()
}

fn send(addr: std::net::SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap()
}

fn sample_profile_line() -> String {
    // real-ish aggregated profile: measured on the simulator
    let w = repro::sim::Workload::new(repro::models::ModelId::ResNet18, 32, 64);
    let run = repro::sim::run_workload(&w, Instance::G4dn).unwrap();
    let mut profile = Json::obj();
    for (k, v) in run.profile.aggregated() {
        profile.set(&k, Json::Num(v));
    }
    let mut req = Json::obj();
    req.set("op", Json::Str("predict".into()));
    req.set("anchor", Json::Str("g4dn".into()));
    req.set("target", Json::Str("p3".into()));
    req.set("anchor_latency_ms", Json::Num(run.latency_ms));
    req.set("profile", profile);
    req.to_string()
}

#[test]
fn serves_health_instances_predict_and_errors() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    // health
    let h = send(addr, r#"{"op":"health"}"#);
    assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));

    // instances
    let i = send(addr, r#"{"op":"instances"}"#);
    assert_eq!(i.req_arr("instances").unwrap().len(), 6);

    // predict (end to end through feature space + ensemble + HLO forward)
    let p = send(addr, &sample_profile_line());
    assert_eq!(p.get("ok").and_then(Json::as_bool), Some(true), "{p:?}");
    let lat = p.req_f64("latency_ms").unwrap();
    assert!(lat > 1.0 && lat < 10_000.0, "latency {lat}");
    assert!(p.req_str("member").is_ok());

    // phase-2 batch interpolation
    let b = send(
        addr,
        r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0,"t_max":900.0}"#,
    );
    let v = b.req_f64("latency_ms").unwrap();
    assert!(v > 50.0 && v < 1000.0, "{v}");

    // serving stats reflect the traffic so far
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("requests").unwrap() >= 2.0);
    assert!(st.req_f64("artifact_batches").unwrap() >= 1.0);

    // errors: bad op (structured, with a kind tag), unknown pair
    let e = send(addr, r#"{"op":"nope"}"#);
    assert_eq!(e.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(e.req_str("kind").unwrap(), "unknown_op");
    let e2 = send(
        addr,
        r#"{"op":"predict","anchor":"p2","target":"g3s","anchor_latency_ms":1,"profile":{"Conv2D":1}}"#,
    );
    assert_eq!(e2.get("ok").and_then(Json::as_bool), Some(false));

    handle.stop();
}

/// Build a `recommend`/`plan` payload body: ResNet18@p64 profiled on the
/// g4dn anchor at the batch endpoints (b=16 / b=256).
fn advisor_body() -> Json {
    use repro::models::ModelId;
    use repro::sim::Workload;
    let mut body = Json::obj();
    body.set("anchor", Json::Str("g4dn".into()));
    body.set("pixels", Json::Num(64.0));
    for (batch, profile_key, lat_key) in [
        (16usize, "profile_bmin", "anchor_lat_bmin"),
        (256, "profile_bmax", "anchor_lat_bmax"),
    ] {
        let w = Workload::new(ModelId::ResNet18, batch, 64);
        let run = repro::sim::run_workload(&w, Instance::G4dn).unwrap();
        let mut profile = Json::obj();
        for (k, v) in run.profile.aggregated() {
            profile.set(&k, Json::Num(v));
        }
        body.set(profile_key, profile);
        body.set(lat_key, Json::Num(run.latency_ms));
    }
    body.set("gpu_counts", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
    body.set("include_spot", Json::Bool(true));
    body
}

#[test]
fn recommend_ranking_is_pareto_consistent() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let mut req = advisor_body();
    req.set("op", Json::Str("recommend".into()));
    let resp = send(handle.addr, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");

    let cands = resp.req_arr("candidates").unwrap();
    assert!(!cands.is_empty());
    assert_eq!(resp.req_f64("n_candidates").unwrap() as usize, cands.len());
    // both the anchor itself and the modeled target must appear
    for key in ["g4dn", "p3"] {
        assert!(
            cands.iter().any(|c| c.req_str("target").unwrap() == key),
            "missing {key} in candidates"
        );
    }

    // ranking: non-decreasing cost-efficiency
    let costs: Vec<f64> = cands
        .iter()
        .map(|c| c.req_f64("cost_per_img_usd").unwrap())
        .collect();
    for w in costs.windows(2) {
        assert!(w[0] <= w[1], "ranking not sorted by cost: {costs:?}");
    }

    // Pareto frontier flags must match a brute-force reference over the
    // advertised objective pair (seconds/image, $/image)
    let points: Vec<(f64, f64)> = cands
        .iter()
        .map(|c| {
            (
                1.0 / c.req_f64("imgs_per_s").unwrap(),
                c.req_f64("cost_per_img_usd").unwrap(),
            )
        })
        .collect();
    let reference: std::collections::BTreeSet<usize> =
        repro::advisor::pareto_frontier_naive(&points).into_iter().collect();
    for (i, c) in cands.iter().enumerate() {
        assert_eq!(
            c.get("on_frontier").and_then(Json::as_bool),
            Some(reference.contains(&i)),
            "frontier flag mismatch at rank {i}: {c:?}"
        );
    }
    assert_eq!(resp.req_f64("frontier_size").unwrap() as usize, reference.len());

    // sanity: every candidate latency is positive and finite
    for c in cands {
        let lat = c.req_f64("latency_ms").unwrap();
        assert!(lat > 0.0 && lat.is_finite(), "{lat}");
    }
    handle.stop();
}

#[test]
fn plan_answers_constrained_queries() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();

    let mut req = advisor_body();
    req.set("op", Json::Str("plan".into()));
    req.set("objective", Json::Str("cheapest".into()));
    req.set("deadline_hours", Json::Num(10_000.0));
    req.set("dataset_images", Json::Num(50_000.0));
    req.set("epochs", Json::Num(5.0));
    let resp = send(handle.addr, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let choice = resp.get("choice").expect("choice");
    assert!(choice.req_f64("latency_ms").unwrap() > 0.0);
    let hours = resp.req_f64("hours").unwrap();
    let cost = resp.req_f64("cost_usd").unwrap();
    assert!(hours > 0.0 && hours <= 10_000.0);
    assert!(cost > 0.0);
    // the generous-deadline cheapest choice is the globally cheapest
    // candidate: its job cost must match hours * price_hr
    let price_hr = choice.req_f64("price_hr").unwrap();
    assert!((cost - hours * price_hr).abs() < 1e-9 * cost.max(1.0));

    // an impossible deadline is a structured infeasibility, not a crash
    let mut req = advisor_body();
    req.set("op", Json::Str("plan".into()));
    req.set("objective", Json::Str("cheapest".into()));
    req.set("deadline_hours", Json::Num(1e-9));
    req.set("dataset_images", Json::Num(50_000.0));
    req.set("epochs", Json::Num(5.0));
    let resp = send(handle.addr, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(resp.req_str("kind").unwrap(), "infeasible");
    handle.stop();
}

#[test]
fn repeated_predict_hits_cache_bitwise_identical() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();

    let first = send(addr, &line);
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true), "{first:?}");
    let hits_before = handle
        .stats
        .cache
        .hits
        .load(std::sync::atomic::Ordering::Relaxed);
    let second = send(addr, &line);

    // bitwise-identical prediction (and the same ensemble member)
    assert_eq!(
        first.req_f64("latency_ms").unwrap().to_bits(),
        second.req_f64("latency_ms").unwrap().to_bits()
    );
    assert_eq!(
        first.req_str("member").unwrap(),
        second.req_str("member").unwrap()
    );

    // the repeat was served from the cache, and the stats op surfaces it
    let hits_after = handle
        .stats
        .cache
        .hits
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(hits_after > hits_before, "{hits_before} -> {hits_after}");
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("cache_hits").unwrap() >= 1.0);
    assert!(st.req_f64("cache_misses").unwrap() >= 1.0);
    handle.stop();
}

#[test]
fn oversized_request_line_gets_structured_error() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let mut stream = TcpStream::connect(handle.addr).unwrap();
    // an oversized garbage line, then a valid request on the same conn
    let big = vec![b'x'; coordinator::MAX_LINE_BYTES + 128];
    stream.write_all(&big).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.write_all(br#"{"op":"health"}"#).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let e = Json::parse(resp.trim()).unwrap();
    assert_eq!(e.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(e.req_str("kind").unwrap(), "line_too_long");
    // the connection survives and serves the next line
    resp.clear();
    reader.read_line(&mut resp).unwrap();
    let h = Json::parse(resp.trim()).unwrap();
    assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));
    handle.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();

    let n = 24;
    let mut joins = Vec::new();
    for _ in 0..n {
        let line = line.clone();
        joins.push(std::thread::spawn(move || send(addr, &line)));
    }
    let mut latencies = Vec::new();
    for j in joins {
        let resp = j.join().unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        latencies.push(resp.req_f64("latency_ms").unwrap());
    }
    // identical request → identical prediction, through any batch grouping
    for l in &latencies {
        assert!((l - latencies[0]).abs() < 1e-6);
    }
    handle.stop();
}
