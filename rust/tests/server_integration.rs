//! Coordinator integration: boot the full TCP service on an ephemeral
//! port, train + save a model directory, then drive it like a client —
//! including concurrent requests that exercise the dynamic batcher, a
//! `recommend` sweep racing a `predict` stream (head-of-line isolation
//! across engine lanes), queue backpressure, graceful drain, and the
//! live model registry (`reload`/`ingest`/`onboard` hot swaps racing
//! predict traffic, failed-validation rollback, load-time completeness).

use repro::coordinator;
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::predictor::{Profet, TrainOptions};
use repro::runtime;
use repro::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::OnceLock;

/// Train once per test binary, save to a shared temp dir. `None` when the
/// runtime backend is unavailable (offline build with the xla shim).
fn model_dir() -> Option<&'static std::path::PathBuf> {
    static DIR: OnceLock<Option<std::path::PathBuf>> = OnceLock::new();
    DIR.get_or_init(|| {
        let rt = match runtime::load_default() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping server tests: runtime unavailable: {e:#}");
                return None;
            }
        };
        let corpus = Corpus::generate(&[Instance::G4dn, Instance::P3]);
        let (train_idx, _) = corpus.split_random(0.1, 11);
        let opts = TrainOptions {
            anchors: vec![Instance::G4dn],
            targets: vec![Instance::P3],
            clustering: true,
            poly_order: 2,
            n_trees: 15,
            dnn_epochs: 8,
            seed: 99,
        };
        let profet = Profet::train(&rt, &corpus, &train_idx, &opts).unwrap();
        let dir = std::env::temp_dir().join("repro_server_models");
        std::fs::remove_dir_all(&dir).ok();
        profet.save(&dir).unwrap();
        Some(dir)
    })
    .as_ref()
}

/// Copy the shared trained model dir into a private scratch dir — the
/// registry tests mutate their model directory (reload/onboard/corrupt),
/// which must never race the read-only tests sharing `model_dir()`.
fn copy_model_dir(tag: &str) -> std::path::PathBuf {
    let src = model_dir().expect("caller checked");
    let dst = std::env::temp_dir().join(format!("repro_server_models_{tag}"));
    std::fs::remove_dir_all(&dst).ok();
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let path = entry.unwrap().path();
        if path.is_file() {
            std::fs::copy(&path, dst.join(path.file_name().unwrap())).unwrap();
        }
    }
    dst
}

fn send(addr: std::net::SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap()
}

fn sample_profile_line() -> String {
    // real-ish aggregated profile: measured on the simulator
    let w = repro::sim::Workload::new(repro::models::ModelId::ResNet18, 32, 64);
    let run = repro::sim::run_workload(&w, Instance::G4dn).unwrap();
    let mut profile = Json::obj();
    for (k, v) in run.profile.aggregated() {
        profile.set(&k, Json::Num(v));
    }
    let mut req = Json::obj();
    req.set("op", Json::Str("predict".into()));
    req.set("anchor", Json::Str("g4dn".into()));
    req.set("target", Json::Str("p3".into()));
    req.set("anchor_latency_ms", Json::Num(run.latency_ms));
    req.set("profile", profile);
    req.to_string()
}

/// Cache-bust a predict line: nudge `anchor_latency_ms` by whole
/// quantization buckets (cf. `big_sweep_line`) so each variant gets a
/// distinct prediction-cache key and must take the engine-lane miss
/// path instead of the router's warm-hit fast path.
fn bust_predict_line(line: &str, bust: usize) -> String {
    let mut req = Json::parse(line).unwrap();
    let v = req.req_f64("anchor_latency_ms").unwrap();
    req.set("anchor_latency_ms", Json::Num(v * (1.0 + bust as f64 * 1e-3)));
    req.to_string()
}

#[test]
fn serves_health_instances_predict_and_errors() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    // health
    let h = send(addr, r#"{"op":"health"}"#);
    assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));

    // instances
    let i = send(addr, r#"{"op":"instances"}"#);
    assert_eq!(i.req_arr("instances").unwrap().len(), 6);

    // predict (end to end through feature space + ensemble + HLO forward)
    let p = send(addr, &sample_profile_line());
    assert_eq!(p.get("ok").and_then(Json::as_bool), Some(true), "{p:?}");
    let lat = p.req_f64("latency_ms").unwrap();
    assert!(lat > 1.0 && lat < 10_000.0, "latency {lat}");
    assert!(p.req_str("member").is_ok());

    // phase-2 batch interpolation
    let b = send(
        addr,
        r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0,"t_max":900.0}"#,
    );
    let v = b.req_f64("latency_ms").unwrap();
    assert!(v > 50.0 && v < 1000.0, "{v}");

    // serving stats reflect the traffic so far, including the reactor
    // tier's connection health (each send() opens its own connection, so
    // only the stats connection itself is necessarily still open)
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("requests").unwrap() >= 2.0);
    assert!(st.req_f64("artifact_batches").unwrap() >= 1.0);
    assert!(st.req_f64("reactor_threads").unwrap() >= 1.0);
    assert!(st.req_f64("open_conns").unwrap() >= 1.0);
    let open = st.req_f64("open_conns").unwrap();
    let active = st.req_f64("active_conns").unwrap();
    let idle = st.req_f64("idle_conns").unwrap();
    assert_eq!(active + idle, open, "conn gauge split must add up");
    assert_eq!(st.req_f64("evictions").unwrap(), 0.0, "no idle timeout configured");

    // errors: bad op (structured, with a kind tag), unknown pair
    let e = send(addr, r#"{"op":"nope"}"#);
    assert_eq!(e.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(e.req_str("kind").unwrap(), "unknown_op");
    let e2 = send(
        addr,
        r#"{"op":"predict","anchor":"p2","target":"g3s","anchor_latency_ms":1,"profile":{"Conv2D":1}}"#,
    );
    assert_eq!(e2.get("ok").and_then(Json::as_bool), Some(false));

    handle.stop();
}

/// Build a `recommend`/`plan` payload body: ResNet18@p64 profiled on the
/// g4dn anchor at the batch endpoints (b=16 / b=256).
fn advisor_body() -> Json {
    use repro::models::ModelId;
    use repro::sim::Workload;
    let mut body = Json::obj();
    body.set("anchor", Json::Str("g4dn".into()));
    body.set("pixels", Json::Num(64.0));
    for (batch, profile_key, lat_key) in [
        (16usize, "profile_bmin", "anchor_lat_bmin"),
        (256, "profile_bmax", "anchor_lat_bmax"),
    ] {
        let w = Workload::new(ModelId::ResNet18, batch, 64);
        let run = repro::sim::run_workload(&w, Instance::G4dn).unwrap();
        let mut profile = Json::obj();
        for (k, v) in run.profile.aggregated() {
            profile.set(&k, Json::Num(v));
        }
        body.set(profile_key, profile);
        body.set(lat_key, Json::Num(run.latency_ms));
    }
    body.set("gpu_counts", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
    body.set("include_spot", Json::Bool(true));
    body
}

#[test]
fn recommend_ranking_is_pareto_consistent() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let mut req = advisor_body();
    req.set("op", Json::Str("recommend".into()));
    let resp = send(handle.addr, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");

    let cands = resp.req_arr("candidates").unwrap();
    assert!(!cands.is_empty());
    assert_eq!(resp.req_f64("n_candidates").unwrap() as usize, cands.len());
    // both the anchor itself and the modeled target must appear
    for key in ["g4dn", "p3"] {
        assert!(
            cands.iter().any(|c| c.req_str("target").unwrap() == key),
            "missing {key} in candidates"
        );
    }

    // ranking: non-decreasing cost-efficiency
    let costs: Vec<f64> = cands
        .iter()
        .map(|c| c.req_f64("cost_per_img_usd").unwrap())
        .collect();
    for w in costs.windows(2) {
        assert!(w[0] <= w[1], "ranking not sorted by cost: {costs:?}");
    }

    // Pareto frontier flags must match a brute-force reference over the
    // advertised objective pair (seconds/image, $/image)
    let points: Vec<(f64, f64)> = cands
        .iter()
        .map(|c| {
            (
                1.0 / c.req_f64("imgs_per_s").unwrap(),
                c.req_f64("cost_per_img_usd").unwrap(),
            )
        })
        .collect();
    let reference: std::collections::BTreeSet<usize> =
        repro::advisor::pareto_frontier_naive(&points).into_iter().collect();
    for (i, c) in cands.iter().enumerate() {
        assert_eq!(
            c.get("on_frontier").and_then(Json::as_bool),
            Some(reference.contains(&i)),
            "frontier flag mismatch at rank {i}: {c:?}"
        );
    }
    assert_eq!(resp.req_f64("frontier_size").unwrap() as usize, reference.len());

    // sanity: every candidate latency is positive and finite
    for c in cands {
        let lat = c.req_f64("latency_ms").unwrap();
        assert!(lat > 0.0 && lat.is_finite(), "{lat}");
    }
    handle.stop();
}

#[test]
fn plan_answers_constrained_queries() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();

    let mut req = advisor_body();
    req.set("op", Json::Str("plan".into()));
    req.set("objective", Json::Str("cheapest".into()));
    req.set("deadline_hours", Json::Num(10_000.0));
    req.set("dataset_images", Json::Num(50_000.0));
    req.set("epochs", Json::Num(5.0));
    let resp = send(handle.addr, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    let choice = resp.get("choice").expect("choice");
    assert!(choice.req_f64("latency_ms").unwrap() > 0.0);
    let hours = resp.req_f64("hours").unwrap();
    let cost = resp.req_f64("cost_usd").unwrap();
    assert!(hours > 0.0 && hours <= 10_000.0);
    assert!(cost > 0.0);
    // the generous-deadline cheapest choice is the globally cheapest
    // candidate: its job cost must match hours * price_hr
    let price_hr = choice.req_f64("price_hr").unwrap();
    assert!((cost - hours * price_hr).abs() < 1e-9 * cost.max(1.0));

    // an impossible deadline is a structured infeasibility, not a crash
    let mut req = advisor_body();
    req.set("op", Json::Str("plan".into()));
    req.set("objective", Json::Str("cheapest".into()));
    req.set("deadline_hours", Json::Num(1e-9));
    req.set("dataset_images", Json::Num(50_000.0));
    req.set("epochs", Json::Num(5.0));
    let resp = send(handle.addr, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(resp.req_str("kind").unwrap(), "infeasible");
    handle.stop();
}

#[test]
fn repeated_predict_hits_cache_bitwise_identical() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();

    let first = send(addr, &line);
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true), "{first:?}");
    let hits_before = handle
        .stats
        .cache
        .hits
        .load(std::sync::atomic::Ordering::Relaxed);
    let second = send(addr, &line);

    // bitwise-identical prediction (and the same ensemble member)
    assert_eq!(
        first.req_f64("latency_ms").unwrap().to_bits(),
        second.req_f64("latency_ms").unwrap().to_bits()
    );
    assert_eq!(
        first.req_str("member").unwrap(),
        second.req_str("member").unwrap()
    );

    // the repeat was served from the cache, and the stats op surfaces it
    let hits_after = handle
        .stats
        .cache
        .hits
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(hits_after > hits_before, "{hits_before} -> {hits_after}");
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("cache_hits").unwrap() >= 1.0);
    assert!(st.req_f64("cache_misses").unwrap() >= 1.0);
    handle.stop();
}

#[test]
fn oversized_request_line_gets_structured_error() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let mut stream = TcpStream::connect(handle.addr).unwrap();
    // an oversized garbage line, then a valid request on the same conn
    let big = vec![b'x'; coordinator::MAX_LINE_BYTES + 128];
    stream.write_all(&big).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.write_all(br#"{"op":"health"}"#).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let e = Json::parse(resp.trim()).unwrap();
    assert_eq!(e.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(e.req_str("kind").unwrap(), "line_too_long");
    // the connection survives and serves the next line
    resp.clear();
    reader.read_line(&mut resp).unwrap();
    let h = Json::parse(resp.trim()).unwrap();
    assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));
    handle.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();

    let n = 24;
    let mut joins = Vec::new();
    for _ in 0..n {
        let line = line.clone();
        joins.push(std::thread::spawn(move || send(addr, &line)));
    }
    let mut latencies = Vec::new();
    for j in joins {
        let resp = j.join().unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        latencies.push(resp.req_f64("latency_ms").unwrap());
    }
    // identical request → identical prediction, through any batch grouping
    for l in &latencies {
        assert!((l - latencies[0]).abs() < 1e-6);
    }
    handle.stop();
}

/// A large `recommend` grid request body: the full batch grid plus every
/// GPU count that divides a paper batch size (so the multi-GPU scaling
/// calibration actually runs), optionally cache-busted so repeat sweeps
/// redo their phase-1 ensemble executions instead of hitting the cache.
fn big_sweep_line(bust: usize) -> String {
    let mut req = advisor_body();
    req.set("op", Json::Str("recommend".into()));
    req.set(
        "batches",
        Json::Arr(vec![16.0, 32.0, 64.0, 128.0, 256.0].into_iter().map(Json::Num).collect()),
    );
    req.set(
        "gpu_counts",
        Json::Arr(
            vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
                .into_iter()
                .map(Json::Num)
                .collect(),
        ),
    );
    if bust > 0 {
        // nudge the endpoint latencies by whole quantization buckets:
        // distinct cache keys, still positive and physically plausible
        for key in ["anchor_lat_bmin", "anchor_lat_bmax"] {
            let v = req.req_f64(key).unwrap();
            req.set(key, Json::Num(v * (1.0 + bust as f64 * 1e-3)));
        }
    }
    req.to_string()
}

/// THE head-of-line regression test: a stream of `predict`s must complete
/// while `recommend` sweeps are still in flight on the advisor lane —
/// predicts never queue behind a sweep (the seed's single engine thread
/// serialized them).
#[test]
fn predicts_are_not_blocked_by_inflight_recommend_sweeps() {
    let Some(models) = model_dir() else { return };
    let opts = coordinator::ServeOptions {
        pool: coordinator::PoolOptions {
            predict_lanes: 2,
            ..coordinator::PoolOptions::default()
        },
        ..coordinator::ServeOptions::default()
    };
    let handle = coordinator::serve_with(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
        &opts,
    )
    .unwrap();
    let addr = handle.addr;

    // warm the predict path so the measured stream is steady-state
    let line = sample_profile_line();
    let warm = send(addr, &line);
    assert_eq!(warm.get("ok").and_then(Json::as_bool), Some(true), "{warm:?}");

    // advisor thread: back-to-back sweeps keep the advisor lane busy for
    // the whole predict stream. Sweep #0 pays the multi-GPU calibration
    // (dozens of simulator runs — by far the slowest request in flight);
    // each later sweep is cache-busted so it re-executes its phase-1
    // ensembles.
    let n_sweeps = 6;
    let sweeps = std::thread::spawn(move || {
        let mut oks = 0;
        let mut durations = Vec::new();
        for i in 0..n_sweeps {
            let t = std::time::Instant::now();
            let resp = send(addr, &big_sweep_line(i));
            durations.push(t.elapsed());
            if resp.get("ok").and_then(Json::as_bool) == Some(true) {
                oks += 1;
            }
        }
        (oks, durations, std::time::Instant::now())
    });

    // three parallel predict clients start while sweep #0 is in flight.
    // Every measured line is CACHE-BUSTED (distinct anchor latency →
    // distinct prediction-cache key): the router's warm-hit fast path
    // must not answer them, or this gate would stop exercising the
    // engine lanes entirely — the misses still share (anchor, target),
    // so they land on one affinity lane and coalesce in its batch window
    std::thread::sleep(std::time::Duration::from_millis(2));
    let mut clients = Vec::new();
    for c in 0..3usize {
        let line = line.clone();
        clients.push(std::thread::spawn(move || {
            let mut max_rtt = std::time::Duration::ZERO;
            for k in 0..4usize {
                let busted = bust_predict_line(&line, 1 + c * 4 + k);
                let t = std::time::Instant::now();
                let resp = send(addr, &busted);
                max_rtt = max_rtt.max(t.elapsed());
                assert_eq!(
                    resp.get("ok").and_then(Json::as_bool),
                    Some(true),
                    "{resp:?}"
                );
            }
            (max_rtt, std::time::Instant::now())
        }));
    }
    let results: Vec<(std::time::Duration, std::time::Instant)> =
        clients.into_iter().map(|j| j.join().unwrap()).collect();
    let max_rtt = results.iter().map(|r| r.0).max().unwrap();
    let predicts_done = results.iter().map(|r| r.1).max().unwrap();

    let (sweep_oks, sweep_durations, sweeps_done) = sweeps.join().unwrap();
    assert_eq!(sweep_oks, n_sweeps);
    // THE head-of-line assertion: the worst predict round-trip must be
    // far below the cold sweep's duration. Under a serialized engine the
    // predicts (issued 2 ms into sweep #0) would queue behind it and the
    // worst RTT would be ≈ that sweep's whole duration — here it must be
    // under half of it. (Both sides scale together under CI load: slower
    // simulators make the cold sweep proportionally longer.)
    let cold = sweep_durations[0];
    assert!(
        max_rtt * 2 < cold,
        "predict RTT {max_rtt:?} is not clearly below the in-flight cold \
         sweep ({cold:?}) — predicts are queueing behind the advisor lane"
    );
    // secondary overlap check: the predict stream finished while the
    // sweep backlog was still draining
    assert!(
        predicts_done < sweeps_done,
        "predict stream did not overlap the sweeps \
         (predicts finished {:?} after the sweeps)",
        predicts_done.duration_since(sweeps_done)
    );
    let st = send(addr, r#"{"op":"stats"}"#);
    assert_eq!(st.req_f64("predict_lanes").unwrap() as usize, 2);
    handle.stop();
}

/// Cross-replica cache coherence: a phase-1 prediction computed on a
/// *predict lane* must be visible to the *advisor lane*'s sweep (and
/// counted exactly once in the shared hit/miss counters), because the
/// sharded cache is one `Arc` across all replicas.
#[test]
fn prediction_cache_is_shared_across_replicas() {
    let Some(models) = model_dir() else { return };
    let opts = coordinator::ServeOptions {
        pool: coordinator::PoolOptions {
            predict_lanes: 2,
            ..coordinator::PoolOptions::default()
        },
        ..coordinator::ServeOptions::default()
    };
    let handle = coordinator::serve_with(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
        &opts,
    )
    .unwrap();
    let addr = handle.addr;

    // the sweep's two batch endpoints, first issued as plain predicts
    // (served by a predict-lane replica, populating the shared cache)
    let body = advisor_body();
    for (profile_key, lat_key) in [
        ("profile_bmin", "anchor_lat_bmin"),
        ("profile_bmax", "anchor_lat_bmax"),
    ] {
        let mut req = Json::obj();
        req.set("op", Json::Str("predict".into()));
        req.set("anchor", Json::Str("g4dn".into()));
        req.set("target", Json::Str("p3".into()));
        req.set("anchor_latency_ms", Json::Num(body.req_f64(lat_key).unwrap()));
        req.set("profile", body.get(profile_key).unwrap().clone());
        let resp = send(addr, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    }
    let hits_before = handle.stats.cache.hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses_before = handle.stats.cache.misses.load(std::sync::atomic::Ordering::Relaxed);

    // the recommend sweep (advisor-lane replica) looks up exactly those
    // two endpoint keys for target p3 — both must hit the shared cache
    let mut req = advisor_body();
    req.set("op", Json::Str("recommend".into()));
    req.set("targets", Json::Arr(vec![Json::Str("p3".into())]));
    let resp = send(addr, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");

    let hits_after = handle.stats.cache.hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses_after = handle.stats.cache.misses.load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        hits_after >= hits_before + 2,
        "sweep did not hit the predict-lane cache entries: {hits_before} -> {hits_after}"
    );
    assert_eq!(
        misses_after, misses_before,
        "sweep re-computed endpoints that another replica already cached"
    );
    handle.stop();
}

/// Backpressure: with a 1-deep advisor queue, a burst of concurrent
/// sweeps must shed load with the structured `overloaded` error instead
/// of buffering unboundedly — and the shed count is surfaced via `stats`.
#[test]
fn advisor_queue_overflow_is_structured_overloaded() {
    let Some(models) = model_dir() else { return };
    let opts = coordinator::ServeOptions {
        pool: coordinator::PoolOptions {
            predict_lanes: 1,
            advisor_queue_cap: 1,
            ..coordinator::PoolOptions::default()
        },
        ..coordinator::ServeOptions::default()
    };
    let handle = coordinator::serve_with(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
        &opts,
    )
    .unwrap();
    let addr = handle.addr;

    let burst = 8;
    let mut joins = Vec::new();
    for _ in 0..burst {
        joins.push(std::thread::spawn(move || send(addr, &big_sweep_line(0))));
    }
    let mut oks = 0;
    let mut overloaded = 0;
    for j in joins {
        let resp = j.join().unwrap();
        match resp.get("ok").and_then(Json::as_bool) {
            Some(true) => oks += 1,
            _ => {
                assert_eq!(resp.req_str("kind").unwrap(), "overloaded", "{resp:?}");
                overloaded += 1;
            }
        }
    }
    // at least one sweep ran and at least one was shed (8 concurrent
    // sweeps vs 1 running + 1 queued can't all be accepted)
    assert!(oks >= 1, "no sweep served");
    assert!(overloaded >= 1, "no sweep shed: oks={oks}");
    assert_eq!(oks + overloaded, burst);
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("overloaded").unwrap() >= overloaded as f64);
    // predict traffic rode through the whole overload episode
    let p = send(addr, &sample_profile_line());
    assert_eq!(p.get("ok").and_then(Json::as_bool), Some(true), "{p:?}");
    handle.stop();
}

/// Graceful drain: `stop()` returns only after in-flight connections got
/// their responses — a request already accepted by the engine is never
/// answered with a dropped connection.
#[test]
fn stop_drains_inflight_sweep_response() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(big_sweep_line(0).as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    });
    // wait until the sweep has provably reached the advisor lane (the
    // requests counter ticks when the lane STARTS a job), then drain
    // mid-flight — a fixed sleep would race connection scheduling
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while handle.stats.requests.load(std::sync::atomic::Ordering::Relaxed) == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "sweep never reached the engine"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    handle.stop();
    // stop() already returned — the response must nevertheless be whole
    let resp = client.join().unwrap();
    let j = Json::parse(resp.trim()).expect("in-flight response lost during drain");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
}

// ---------------------------------------------------------------------------
// Live model registry: hot reload, onboarding, rollback
// ---------------------------------------------------------------------------

/// THE registry swap test: `reload` issued against a running server
/// publishes new epochs while concurrent predicts are in flight — every
/// predict succeeds (none dropped, none errored), `stats.registry_epoch`
/// increments, and post-swap traffic refills the cache under the new
/// epoch (first repeat is a miss, second a hit).
#[test]
fn reload_publishes_new_epoch_without_dropping_concurrent_predicts() {
    let Some(_) = model_dir() else { return };
    let models = copy_model_dir("reload");
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    // boot state: epoch 1, never reloaded
    let st = send(addr, r#"{"op":"stats"}"#);
    assert_eq!(st.req_f64("registry_epoch").unwrap() as u64, 1);
    assert_eq!(st.req_f64("last_reload").unwrap() as u64, 0);

    // warm one line under epoch 1 (miss, then hit)
    let line = sample_profile_line();
    let first = send(addr, &line);
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true), "{first:?}");
    let second = send(addr, &line);
    assert_eq!(
        first.req_f64("latency_ms").unwrap().to_bits(),
        second.req_f64("latency_ms").unwrap().to_bits()
    );

    // concurrent predict stream across the swap boundary: cache-busted
    // (distinct keys) so they exercise the full engine path, not just the
    // router's warm hit
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut clients = Vec::new();
    for c in 0..3usize {
        let line = line.clone();
        let stop = stop.clone();
        clients.push(std::thread::spawn(move || {
            let mut n = 0usize;
            let mut k = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) || n < 4 {
                let busted = bust_predict_line(&line, 1 + c * 1000 + k);
                k += 1;
                let resp = send(addr, &busted);
                assert_eq!(
                    resp.get("ok").and_then(Json::as_bool),
                    Some(true),
                    "predict dropped/errored across a reload: {resp:?}"
                );
                n += 1;
                if n > 500 {
                    break; // safety valve under very slow CI
                }
            }
            n
        }));
    }

    // two reloads land mid-stream; each publishes the next epoch
    let r1 = send(addr, r#"{"op":"reload"}"#);
    assert_eq!(r1.get("ok").and_then(Json::as_bool), Some(true), "{r1:?}");
    assert_eq!(r1.req_f64("epoch").unwrap() as u64, 2);
    let r2 = send(addr, r#"{"op":"reload"}"#);
    assert_eq!(r2.req_f64("epoch").unwrap() as u64, 3);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let served: usize = clients.into_iter().map(|j| j.join().unwrap()).sum();
    assert!(served >= 12, "{served}");

    let st = send(addr, r#"{"op":"stats"}"#);
    assert_eq!(st.req_f64("registry_epoch").unwrap() as u64, 3);
    assert!(st.req_f64("last_reload").unwrap() > 0.0);

    // post-swap cache refill: the epoch-1-warm line misses once under
    // epoch 3 (stale entries unreachable, no flush), then hits again
    let misses_before = handle.stats.cache.misses.load(std::sync::atomic::Ordering::Relaxed);
    let hits_before = handle.stats.cache.hits.load(std::sync::atomic::Ordering::Relaxed);
    let again = send(addr, &line);
    assert_eq!(again.get("ok").and_then(Json::as_bool), Some(true), "{again:?}");
    // bitwise-equal to the epoch-1 answer: same models were re-loaded
    assert_eq!(
        again.req_f64("latency_ms").unwrap().to_bits(),
        first.req_f64("latency_ms").unwrap().to_bits()
    );
    let warm = send(addr, &line);
    assert_eq!(warm.get("ok").and_then(Json::as_bool), Some(true));
    let misses_after = handle.stats.cache.misses.load(std::sync::atomic::Ordering::Relaxed);
    let hits_after = handle.stats.cache.hits.load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        misses_after > misses_before,
        "post-swap repeat should be a cache miss under the new epoch"
    );
    assert!(
        hits_after > hits_before,
        "second post-swap repeat should hit the refilled cache"
    );
    handle.stop();
    std::fs::remove_dir_all(&models).ok();
}

/// A candidate that fails the validation gate (here: a model dir whose
/// manifest lists a deleted component) is rejected with a structured
/// error and the previous epoch KEEPS SERVING — asserted via `stats` and
/// by the old pair still answering.
#[test]
fn failed_reload_validation_leaves_previous_epoch_serving() {
    let Some(_) = model_dir() else { return };
    let models = copy_model_dir("badreload");
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;
    let line = sample_profile_line();
    let before = send(addr, &line);
    assert_eq!(before.get("ok").and_then(Json::as_bool), Some(true), "{before:?}");

    // corrupt the dir: the manifest still lists cross_g4dn_p3.json
    std::fs::remove_file(models.join("cross_g4dn_p3.json")).unwrap();
    let r = send(addr, r#"{"op":"reload"}"#);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "{r:?}");
    assert_eq!(r.req_str("kind").unwrap(), "validation_failed");
    assert!(
        r.req_str("error").unwrap().contains("g4dn->p3"),
        "error should name the missing pair: {r:?}"
    );

    // nothing changed: epoch 1 still serving, predictions still answered
    let st = send(addr, r#"{"op":"stats"}"#);
    assert_eq!(st.req_f64("registry_epoch").unwrap() as u64, 1);
    let after = send(addr, &line);
    assert_eq!(after.get("ok").and_then(Json::as_bool), Some(true), "{after:?}");
    assert_eq!(
        before.req_f64("latency_ms").unwrap().to_bits(),
        after.req_f64("latency_ms").unwrap().to_bits()
    );

    // the load-time structured error is also visible to library callers
    let err = repro::predictor::Profet::load(&models).unwrap_err();
    let gap = err
        .downcast_ref::<repro::predictor::MissingModels>()
        .expect("MissingModels in the chain");
    assert_eq!(
        gap.cross,
        vec![(Instance::G4dn, Instance::P3)]
    );
    handle.stop();
    std::fs::remove_dir_all(&models).ok();
}

/// Online onboarding end to end: `ingest` staged measurements for a pair
/// the server has never seen (g4dn→p2), `onboard` trains + publishes it
/// live, and the pair starts answering — with the manifest on disk
/// updated so a restart serves it too.
#[test]
fn ingest_onboard_brings_a_new_pair_live() {
    let Some(_) = model_dir() else { return };
    let models = copy_model_dir("onboard");
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    // onboarding with nothing staged is its own structured error
    let empty = send(addr, r#"{"op":"onboard"}"#);
    assert_eq!(empty.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(empty.req_str("kind").unwrap(), "no_staged_data");

    // the new pair is unknown before onboarding
    let corpus = Corpus::generate(&[Instance::G4dn, Instance::P2]);
    let paired: Vec<&repro::data::Entry> = corpus
        .entries
        .iter()
        .filter(|e| e.runs.contains_key(&Instance::G4dn) && e.runs.contains_key(&Instance::P2))
        .collect();
    assert!(paired.len() >= 30, "{}", paired.len());
    let probe = {
        let ar = &paired[0].runs[&Instance::G4dn];
        let mut req = Json::obj();
        req.set("op", Json::Str("predict".into()));
        req.set("anchor", Json::Str("g4dn".into()));
        req.set("target", Json::Str("p2".into()));
        req.set("anchor_latency_ms", Json::Num(ar.latency_ms));
        let mut prof = Json::obj();
        for (k, v) in &ar.profile {
            prof.set(&k.clone(), Json::Num(*v));
        }
        req.set("profile", prof);
        req.to_string()
    };
    let before = send(addr, &probe);
    assert_eq!(before.get("ok").and_then(Json::as_bool), Some(false), "{before:?}");

    // stage measurements (more than the ≥20 the trainer requires)
    let mut staged = 0;
    for e in paired.iter().take(40) {
        let ar = &e.runs[&Instance::G4dn];
        let tr = &e.runs[&Instance::P2];
        let mut req = Json::obj();
        req.set("op", Json::Str("ingest".into()));
        req.set("anchor", Json::Str("g4dn".into()));
        req.set("target", Json::Str("p2".into()));
        req.set("model", Json::Str(e.workload.model.name().into()));
        req.set("batch", Json::Num(e.workload.batch as f64));
        req.set("pixels", Json::Num(e.workload.pixels as f64));
        let mut prof = Json::obj();
        for (k, v) in &ar.profile {
            prof.set(&k.clone(), Json::Num(*v));
        }
        req.set("profile", prof);
        req.set("anchor_latency_ms", Json::Num(ar.latency_ms));
        req.set("target_latency_ms", Json::Num(tr.latency_ms));
        let resp = send(addr, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        staged = resp.req_f64("staged").unwrap() as usize;
    }
    assert_eq!(staged, 40);

    // onboard: trains on the trainer lane, validates, publishes epoch 2
    let ob = send(addr, r#"{"op":"onboard","anchor":"g4dn","target":"p2"}"#);
    assert_eq!(ob.get("ok").and_then(Json::as_bool), Some(true), "{ob:?}");
    assert_eq!(ob.req_f64("epoch").unwrap() as u64, 2);
    assert_eq!(ob.req_f64("pairs").unwrap() as u64, 1);
    assert_eq!(ob.req_f64("staged").unwrap() as u64, 40);

    // the pair now serves, and the answer is cache-stable
    let after = send(addr, &probe);
    assert_eq!(after.get("ok").and_then(Json::as_bool), Some(true), "{after:?}");
    let lat = after.req_f64("latency_ms").unwrap();
    assert!(lat > 0.0 && lat.is_finite(), "{lat}");
    let again = send(addr, &probe);
    assert_eq!(
        lat.to_bits(),
        again.req_f64("latency_ms").unwrap().to_bits()
    );
    let st = send(addr, r#"{"op":"stats"}"#);
    assert_eq!(st.req_f64("registry_epoch").unwrap() as u64, 2);
    assert!(st.req_f64("last_reload").unwrap() > 0.0);

    // consumed staging was cleared; the old pair still serves
    let old = send(addr, &sample_profile_line());
    assert_eq!(old.get("ok").and_then(Json::as_bool), Some(true), "{old:?}");
    assert!(!models.join("staging").join("g4dn_p2.jsonl").exists());
    handle.stop();

    // the persisted dir (manifest included) round-trips with the new pair
    let loaded = repro::predictor::Profet::load(&models).unwrap();
    assert!(loaded.cross.contains_key(&(Instance::G4dn, Instance::P2)));
    // ...and deleting the freshly onboarded component is caught at load
    std::fs::remove_file(models.join("cross_g4dn_p2.json")).unwrap();
    let err = repro::predictor::Profet::load(&models).unwrap_err();
    let gap = err
        .downcast_ref::<repro::predictor::MissingModels>()
        .expect("MissingModels in the chain");
    assert_eq!(gap.cross, vec![(Instance::G4dn, Instance::P2)]);
    std::fs::remove_dir_all(&models).ok();
}

/// Sum of `sum_ms` over every cell of the named stage in a `metrics`
/// reply (all ops, warm + cold).
fn stage_sum_ms(metrics: &Json, stage: &str) -> f64 {
    let mut total = 0.0;
    for s in metrics.req_arr("stages").unwrap() {
        if s.req_str("stage").unwrap() != stage {
            continue;
        }
        for cell in s.req_arr("cells").unwrap() {
            total += cell.req_f64("sum_ms").unwrap();
        }
    }
    total
}

/// Total sample count over every cell of the named stage.
fn stage_count(metrics: &Json, stage: &str) -> u64 {
    let mut total = 0u64;
    for s in metrics.req_arr("stages").unwrap() {
        if s.req_str("stage").unwrap() != stage {
            continue;
        }
        for cell in s.req_arr("cells").unwrap() {
            total += cell.req_f64("count").unwrap() as u64;
        }
    }
    total
}

/// The latency observatory end to end: mixed warm/cold traffic populates
/// per-stage histograms the `metrics` op exposes, server-side queue-wait
/// + execute time never exceeds what the client observed (the stages are
/// a decomposition of the round trip, not an independent estimate), and
/// the connection-gauge snapshot is torn-read-free even with a sweep in
/// flight.
#[test]
fn metrics_observatory_reflects_mixed_traffic() {
    let Some(models) = model_dir() else { return };
    let handle = coordinator::serve(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
    )
    .unwrap();
    let addr = handle.addr;

    // serial mixed traffic, wall-clocked as one window: every server-side
    // stage sample recorded below happened inside this window
    let t0 = std::time::Instant::now();
    let line = sample_profile_line();
    let n_cold = 5usize;
    for bust in 0..n_cold {
        // bust 0 = the base line (cold on first sight), 1.. = distinct keys
        let l = if bust == 0 { line.clone() } else { bust_predict_line(&line, bust) };
        let resp = send(addr, &l);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    }
    let n_warm = 4usize;
    for _ in 0..n_warm {
        // exact repeat of the base line: warm cache hit, no engine
        let resp = send(addr, &line);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
    }
    let client_elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;

    // `stats` carries the new uptime/version fields
    let st = send(addr, r#"{"op":"stats"}"#);
    assert!(st.req_f64("uptime_s").unwrap() >= 0.0);
    assert_eq!(st.req_str("version").unwrap(), env!("CARGO_PKG_VERSION"));

    let m = send(addr, r#"{"op":"metrics"}"#);
    assert_eq!(m.get("ok").and_then(Json::as_bool), Some(true), "{m:?}");
    assert!(m.req_f64("uptime_s").unwrap() >= 0.0);
    assert_eq!(m.req_str("version").unwrap(), env!("CARGO_PKG_VERSION"));
    let gauges = m.get("gauges").expect("gauges object");
    assert!(gauges.req_f64("requests").unwrap() >= (n_cold + n_warm) as f64);
    assert!(gauges.req_f64("cache_hits").unwrap() >= n_warm as f64);

    // every engine-bound request passed parse → queue-wait → execute →
    // completion-wait; warm hits only parse + warm-lookup
    for stage in ["parse", "queue_wait", "execute", "completion_wait"] {
        assert!(stage_count(&m, stage) > 0, "stage {stage} recorded nothing");
    }
    assert!(stage_count(&m, "queue_wait") >= n_cold as u64);
    assert!(stage_count(&m, "execute") >= n_cold as u64);
    // warm predicts landed in the warm parse/warm_lookup cells
    let warm_lookups: u64 = stage_count(&m, "warm_lookup");
    assert!(warm_lookups >= (n_cold + n_warm) as u64, "{warm_lookups}");

    // decomposition invariant: with strictly serial traffic the server
    // cannot have spent more queue-wait + execute time than the client
    // waited in total (exact sums, not bucketed quantiles)
    let server_ms = stage_sum_ms(&m, "queue_wait") + stage_sum_ms(&m, "execute");
    assert!(
        server_ms <= client_elapsed_ms,
        "server accounted {server_ms:.3} ms > client observed {client_elapsed_ms:.3} ms"
    );

    // torn-read gate: snapshot the gauges while a sweep holds a
    // connection active — the published triple must still add up
    let mut sweep = TcpStream::connect(addr).unwrap();
    sweep.write_all(big_sweep_line(1).as_bytes()).unwrap();
    sweep.write_all(b"\n").unwrap();
    for _ in 0..10 {
        let st = send(addr, r#"{"op":"stats"}"#);
        let open = st.req_f64("open_conns").unwrap();
        let active = st.req_f64("active_conns").unwrap();
        let idle = st.req_f64("idle_conns").unwrap();
        assert_eq!(active + idle, open, "gauge split tore: {st:?}");
    }
    // drain the sweep so stop() isn't owed a response
    let mut reader = BufReader::new(sweep);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert!(resp.contains("\"ok\":true"), "{resp}");

    handle.stop();
}

/// Slow-request tracing end to end: with the slow threshold at zero and
/// 1-in-1 sampling, a forced engine-path request must appear in the
/// `metrics` slow-trace ring with a full stage breakdown that adds up to
/// its total.
#[test]
fn slow_requests_land_in_the_trace_ring() {
    let Some(models) = model_dir() else { return };
    let opts = coordinator::ServeOptions {
        pool: coordinator::PoolOptions {
            // every sampled engine request qualifies as "slow"
            trace_slow_ms: 0.0,
            trace_sample: 1,
            ..coordinator::PoolOptions::default()
        },
        ..coordinator::ServeOptions::default()
    };
    let handle = coordinator::serve_with(
        "127.0.0.1:0",
        runtime::default_artifact_dir(),
        models.clone(),
        &opts,
    )
    .unwrap();
    let addr = handle.addr;

    let resp = send(addr, &big_sweep_line(1));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");

    let m = send(addr, r#"{"op":"metrics"}"#);
    let traces = m.req_arr("slow_traces").unwrap();
    assert!(!traces.is_empty(), "trace ring empty: {m:?}");
    let t = traces
        .iter()
        .find(|t| t.req_str("op").unwrap() == "recommend")
        .expect("the sweep must be in the ring");
    assert_eq!(t.req_str("temp").unwrap(), "cold");
    let total = t.req_f64("total_ms").unwrap();
    assert!(total > 0.0, "{total}");
    let parts: f64 = [
        "parse_ms",
        "queue_wait_ms",
        "batch_assembly_ms",
        "execute_ms",
        "completion_wait_ms",
        "unattributed_ms",
    ]
    .iter()
    .map(|k| {
        let v = t.req_f64(k).unwrap();
        assert!(v >= 0.0, "{k} negative: {v}");
        v
    })
    .sum();
    // the breakdown decomposes the total (unattributed soaks up drift;
    // tiny float slack from the %.3 wire rounding)
    assert!((parts - total).abs() <= 0.01 * total.max(1.0), "{parts} vs {total}");
    assert!(t.req_f64("execute_ms").unwrap() > 0.0, "sweep spent no execute time?");

    handle.stop();
}
