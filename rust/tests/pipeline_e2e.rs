//! End-to-end pipeline integration: corpus → feature space → ensemble
//! training (including the HLO-driven DNN) → two-phase prediction →
//! persistence round-trip. Uses a reduced configuration (REPRO-fast-like)
//! to stay test-sized while exercising every layer.

use repro::data::Corpus;
use repro::gpu::Instance;
use repro::ml::metrics;
use repro::predictor::{Profet, TrainOptions};
use repro::runtime;

/// Load the runtime or skip the test (the offline build links the xla
/// shim, where artifacts cannot execute).
fn runtime_or_skip(test: &str) -> Option<repro::runtime::Runtime> {
    match runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping {test}: runtime unavailable: {e:#}");
            None
        }
    }
}

fn fast_opts() -> TrainOptions {
    TrainOptions {
        anchors: vec![Instance::G4dn],
        targets: vec![Instance::P3, Instance::P2],
        clustering: true,
        poly_order: 2,
        n_trees: 20,
        dnn_epochs: 12,
        seed: 42,
    }
}

#[test]
fn full_pipeline_cross_instance_accuracy() {
    let Some(rt) = runtime_or_skip("full_pipeline_cross_instance_accuracy") else {
        return;
    };
    let corpus = Corpus::generate(&Instance::CORE);
    assert!(corpus.entries.len() > 200, "corpus too small: {}", corpus.entries.len());
    let (train_idx, test_idx) = corpus.split_random(0.2, 7);

    let profet = Profet::train(&rt, &corpus, &train_idx, &fast_opts()).unwrap();
    assert_eq!(profet.cross.len(), 2, "g4dn->p3 and g4dn->p2");
    assert!(profet.feature_space.n_features() > 5);

    // evaluate on the held-out split
    let mut truth = Vec::new();
    let mut pred = Vec::new();
    for &i in &test_idx {
        let e = &corpus.entries[i];
        let (Some(a), Some(t)) = (e.runs.get(&Instance::G4dn), e.runs.get(&Instance::P3)) else {
            continue;
        };
        let (p, _) = profet
            .predict_cross(&rt, Instance::G4dn, Instance::P3, &a.profile, a.latency_ms)
            .unwrap();
        truth.push(t.latency_ms);
        pred.push(p);
    }
    assert!(truth.len() > 30);
    let mape = metrics::mape(&truth, &pred);
    let r2 = metrics::r2(&truth, &pred);
    assert!(mape < 30.0, "cross-instance MAPE {mape}");
    assert!(r2 > 0.8, "cross-instance R2 {r2}");
}

#[test]
fn two_phase_scenario_prediction() {
    let Some(rt) = runtime_or_skip("two_phase_scenario_prediction") else {
        return;
    };
    let corpus = Corpus::generate(&[Instance::G4dn, Instance::P3]);
    let (train_idx, _) = corpus.split_random(0.1, 3);
    let mut opts = fast_opts();
    opts.targets = vec![Instance::P3]; // corpus only covers g4dn + p3
    // two-phase composition amplifies phase-1 error through Eq. 1
    // denormalization — give the ensemble a little more capacity than the
    // other fast tests.
    opts.n_trees = 40;
    opts.dnn_epochs = 25;
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts).unwrap();

    // find (model, pixels) groups with b=16, 64, 256 on both instances
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(String, usize), BTreeMap<usize, usize>> = BTreeMap::new();
    for (i, e) in corpus.entries.iter().enumerate() {
        if e.runs.contains_key(&Instance::G4dn) && e.runs.contains_key(&Instance::P3) {
            groups
                .entry((e.workload.model.name().into(), e.workload.pixels))
                .or_default()
                .insert(e.workload.batch, i);
        }
    }
    let mut tested = 0;
    let mut apes = Vec::new();
    for batches in groups.values() {
        let (Some(&i16), Some(&i64_), Some(&i256)) =
            (batches.get(&16), batches.get(&64), batches.get(&256))
        else {
            continue;
        };
        let a16 = &corpus.entries[i16].runs[&Instance::G4dn];
        let a256 = &corpus.entries[i256].runs[&Instance::G4dn];
        let truth = corpus.entries[i64_].runs[&Instance::P3].latency_ms;
        let pred = profet
            .predict_scenario(
                &rt,
                Instance::G4dn,
                Instance::P3,
                &a16.profile,
                a16.latency_ms,
                &a256.profile,
                a256.latency_ms,
                64,
            )
            .unwrap();
        // tiny workloads (<20 ms) carry high relative noise; Fig 11
        // aggregates across the whole corpus where they wash out.
        if truth > 20.0 {
            apes.push(100.0 * (pred - truth).abs() / truth);
        }
        tested += 1;
    }
    assert!(tested >= 10, "not enough scenario groups");
    let mape = repro::util::mean(&apes);
    assert!(mape < 40.0, "two-phase scenario MAPE {mape} over {} groups", apes.len());
}

#[test]
fn persistence_roundtrip_preserves_predictions() {
    let Some(rt) = runtime_or_skip("persistence_roundtrip_preserves_predictions") else {
        return;
    };
    let corpus = Corpus::generate(&[Instance::G4dn, Instance::P3]);
    let (train_idx, test_idx) = corpus.split_random(0.2, 5);
    let mut opts = fast_opts();
    opts.targets = vec![Instance::P3];
    opts.dnn_epochs = 6;
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts).unwrap();

    let dir = std::env::temp_dir().join("repro_profet_roundtrip");
    std::fs::remove_dir_all(&dir).ok();
    profet.save(&dir).unwrap();
    let loaded = Profet::load(&dir).unwrap();

    for &i in test_idx.iter().take(10) {
        let e = &corpus.entries[i];
        let Some(a) = e.runs.get(&Instance::G4dn) else { continue };
        let (p1, m1) = profet
            .predict_cross(&rt, Instance::G4dn, Instance::P3, &a.profile, a.latency_ms)
            .unwrap();
        let (p2, m2) = loaded
            .predict_cross(&rt, Instance::G4dn, Instance::P3, &a.profile, a.latency_ms)
            .unwrap();
        assert!((p1 - p2).abs() < 1e-6, "{p1} vs {p2}");
        assert_eq!(m1.name(), m2.name());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn clustering_recovers_unseen_op_latency() {
    // The Fig 13 mechanism, end to end: train WITHOUT MobileNetV2 (the
    // only source of Relu6/DepthwiseConv2dNative), then predict it.
    let Some(rt) = runtime_or_skip("clustering_recovers_unseen_op_latency") else {
        return;
    };
    let corpus = Corpus::generate(&[Instance::G4dn, Instance::P3]);
    let (train_idx, test_idx) = corpus.split_by_model(repro::models::ModelId::MobileNetV2);

    let mut mapes = std::collections::BTreeMap::new();
    for clustering in [false, true] {
        let mut opts = fast_opts();
        opts.targets = vec![Instance::P3];
        opts.clustering = clustering;
        let profet = Profet::train(&rt, &corpus, &train_idx, &opts).unwrap();
        let mut truth = Vec::new();
        let mut pred = Vec::new();
        for &i in &test_idx {
            let e = &corpus.entries[i];
            let (Some(a), Some(t)) = (e.runs.get(&Instance::G4dn), e.runs.get(&Instance::P3))
            else {
                continue;
            };
            let (p, _) = profet
                .predict_cross(&rt, Instance::G4dn, Instance::P3, &a.profile, a.latency_ms)
                .unwrap();
            truth.push(t.latency_ms);
            pred.push(p);
        }
        mapes.insert(clustering, metrics::mape(&truth, &pred));
    }
    // clustering must help the unique-op model (paper: +8.3% to +29.9%)
    assert!(
        mapes[&true] < mapes[&false],
        "clustering off {:.2}% vs on {:.2}%",
        mapes[&false],
        mapes[&true]
    );
}
