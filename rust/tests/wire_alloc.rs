//! Wire-layer allocation gate: a warm `predict` round trip through the
//! serving wire path — streaming decode, cache-key construction, cache
//! peek, observatory stage recording, typed response encode — must
//! perform ZERO heap allocations.
//!
//! The test installs a counting `#[global_allocator]` (one binary, one
//! test fn, so no concurrent test noise) and drives exactly the code the
//! connection handler runs per line (`parse_line` → `PredictView` →
//! `CacheKeyScratch::key` → `PredictionCache::peek` →
//! `Response::encode_line`), including the two `Obs::record_ns` calls the
//! router makes per warm line (parse + warm-lookup stage histograms) —
//! the latency observatory rides the hot path and must stay free too.
//! Engine-side work (channel handoff, batch grouping) is out of scope by
//! design: a *warm* predict is answered from the cache before any engine
//! involvement, so this path IS the whole round trip for steady-state
//! traffic. The allocating `metrics` snapshot op is exercised outside
//! the measured windows (it is cold/monitoring traffic by contract).
//!
//! The registry epoch is woven into the cache key on this path (the
//! router reads it off the snapshot — an atomic load plus an `Arc`
//! refcount bump, no allocation); the key here uses a fixed epoch the
//! same way.
//!
//! Failpoints (`util::failpoint`) are compiled into the serving stack —
//! including the reactor write path — but a disarmed hook is a single
//! relaxed atomic load and a branch, so this gate holds with the chaos
//! harness built in. No test here arms a point; arming only ever
//! happens in `tests/chaos.rs` (a separate process) or by operator
//! request via `REPRO_FAILPOINTS`/`--failpoints`.
//!
//! Run explicitly by `ci/check.sh` (`cargo test -q --test wire_alloc`).

use repro::advisor::{CacheKey, CacheKeyScratch, PredictionCache};
use repro::coordinator::{parse_line, ParsedLine, Request, Response, WireScratch};
use repro::obs::{Obs, OpClass, Stage, Temp};
use repro::predictor::Member;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: every method delegates to `System` unchanged, so the
// GlobalAlloc contract (layout validity, pointer provenance, no
// unwinding) is exactly the system allocator's; the only addition is a
// relaxed counter bump, which cannot allocate or panic.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds GlobalAlloc's contract; forwarded verbatim.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: caller upholds GlobalAlloc's contract; forwarded verbatim.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller upholds GlobalAlloc's contract; `ptr` came from
    // this allocator (i.e. from `System`), so forwarding is sound.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller upholds GlobalAlloc's contract; `ptr` came from
    // this allocator (i.e. from `System`), so forwarding is sound.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// The registry epoch warm requests are pinned to (arbitrary nonzero —
/// the point is that weaving it into the key costs no allocations).
const EPOCH: u64 = 7;

/// One warm predict round trip at the wire layer. Returns the encoded
/// response length so nothing is optimized away.
fn round_trip(
    line: &str,
    wire: &mut WireScratch,
    keys: &mut CacheKeyScratch,
    cache: &PredictionCache,
    obs: &Obs,
    out: &mut Vec<u8>,
) -> usize {
    let t0 = Instant::now();
    let parsed = parse_line(line, wire).expect("valid predict line");
    let parse_ns = t0.elapsed().as_nanos() as u64;
    let ParsedLine::Predict(view) = parsed else {
        panic!("expected a predict view");
    };
    let lk0 = Instant::now();
    let key = keys.key(
        EPOCH,
        view.anchor,
        view.target,
        view.anchor_latency_ms,
        view.pairs(),
    );
    let (latency_ms, member) = cache.peek(&key).expect("warm cache must hit");
    // the two histogram recordings the router makes on every warm hit
    obs.record_ns(Stage::Parse, OpClass::Predict, Temp::Warm, parse_ns);
    obs.record_ns(
        Stage::WarmLookup,
        OpClass::Predict,
        Temp::Warm,
        lk0.elapsed().as_nanos() as u64,
    );
    let resp = Response::Prediction { latency_ms, member };
    resp.encode_line(out);
    out.len()
}

#[test]
fn warm_predict_round_trip_is_zero_allocation() {
    // a realistic-size profile (> 30 ops, well past the ~20-element
    // threshold where std's stable sort starts heap-allocating a merge
    // buffer — the reason sort_dedup_pairs hand-rolls insertion sort),
    // including one \u-escaped key ("MaxPool") so the cow/unescape
    // scratch path is exercised. Keys arrive in non-sorted order on
    // purpose so the sort does real work every line.
    let mut line = String::from(
        r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":42.5,"profile":{"#,
    );
    for i in (0..32).rev() {
        line.push_str(&format!("\"Op{i:02}x\":{}.25,", 100 + i));
    }
    line.push_str(r#""Conv2D":286.0,"FusedBatchNormV3":33.25,"Ma\u0078Pool":14.0,"Relu":26.0}}"#);
    let line = line.as_str();

    let cache = PredictionCache::new(16, 1024);
    let mut wire = WireScratch::default();
    let mut keys = CacheKeyScratch::default();
    let mut out = Vec::new();
    // built before the measured windows: the shard histograms allocate
    // once at construction, never on record
    let obs = Obs::new(250.0, 1);

    // seed the cache through the owned-key constructor (what the engine
    // lane does on the cold miss), NOT through the scratch key — the
    // scratch's byte buffer must stay uniquely owned so it can be reused
    let Ok(Request::Predict(req)) = Request::parse(line) else {
        panic!("parse failed");
    };
    let owned = CacheKey::of(EPOCH, req.anchor, req.target, req.anchor_latency_ms, &req.profile);
    cache.insert(owned, (123.456, Member::Forest));

    // warm every buffer (scratch vecs, unescape string, out buffer) and
    // the thread's observatory shard slot
    for _ in 0..3 {
        assert!(round_trip(line, &mut wire, &mut keys, &cache, &obs, &mut out) > 0);
    }
    let body = String::from_utf8(out.clone()).unwrap();
    assert!(body.contains("\"ok\":true"), "{body}");
    assert!(body.contains("\"latency_ms\":123.456"), "{body}");
    assert!(body.contains("\"member\":\"RandomForest\""), "{body}");

    // measured phase: min over attempts shields against incidental
    // allocations from the test-harness thread
    let mut best = u64::MAX;
    for _ in 0..3 {
        let before = allocs();
        for _ in 0..64 {
            round_trip(line, &mut wire, &mut keys, &cache, &obs, &mut out);
        }
        best = best.min(allocs() - before);
        if best == 0 {
            break;
        }
    }
    assert_eq!(best, 0, "warm predict round trip allocated on the wire path");

    warm_interpolation_and_inline_ops_are_zero_allocation(&obs);
    metrics_round_trip_reports_the_recorded_stages(&obs);
}

/// Second phase, called from the single test fn (one test fn per binary
/// keeps the measured windows free of concurrent-test allocations).
fn warm_interpolation_and_inline_ops_are_zero_allocation(obs: &Obs) {
    let batch_line = r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0,"t_max":900.5}"#;
    let health_line = r#"{"op":"health"}"#;
    let mut wire = WireScratch::default();
    let mut out = Vec::new();

    let cycle = |wire: &mut WireScratch, out: &mut Vec<u8>| {
        // interpolation request: parse to the typed Request (no owned
        // payload), encode its reply shape
        let t0 = Instant::now();
        match parse_line(batch_line, wire) {
            Ok(ParsedLine::Req(Request::PredictBatchSize { batch, .. })) => {
                obs.record_ns(
                    Stage::Parse,
                    OpClass::Predict,
                    Temp::Cold,
                    t0.elapsed().as_nanos() as u64,
                );
                Response::Latency { latency_ms: batch as f64 }.encode_line(out);
            }
            other => panic!("unexpected parse: {:?}", other.is_ok()),
        }
        // inline health round trip, parse stage recorded like the router
        let t0 = Instant::now();
        match parse_line(health_line, wire) {
            Ok(ParsedLine::Req(Request::Health)) => {
                obs.record_ns(
                    Stage::Parse,
                    OpClass::Other,
                    Temp::Cold,
                    t0.elapsed().as_nanos() as u64,
                );
                Response::Health.encode_line(out)
            }
            other => panic!("unexpected parse: {:?}", other.is_ok()),
        }
    };

    for _ in 0..3 {
        cycle(&mut wire, &mut out);
    }
    let mut best = u64::MAX;
    for _ in 0..3 {
        let before = allocs();
        for _ in 0..64 {
            cycle(&mut wire, &mut out);
        }
        best = best.min(allocs() - before);
        if best == 0 {
            break;
        }
    }
    assert_eq!(best, 0, "warm interpolation/inline ops allocated on the wire path");
}

/// Outside the measured windows: the `metrics` op parses on the shared
/// wire path and its reply (built over everything the loops above
/// recorded) encodes to well-formed JSON with the warm cells present.
/// This op allocates by contract — no counter assertions here.
fn metrics_round_trip_reports_the_recorded_stages(obs: &Obs) {
    let mut wire = WireScratch::default();
    match parse_line(r#"{"op":"metrics"}"#, &mut wire) {
        Ok(ParsedLine::Req(Request::Metrics)) => {}
        other => panic!("metrics line did not parse: {:?}", other.is_ok()),
    }
    let snap = repro::obs::MetricsSnapshot {
        uptime_s: obs.uptime_s(),
        gauges: vec![("requests", 0.0)],
        stages: obs.stage_summaries(),
        slow: obs.slow_traces(),
    };
    let mut out = Vec::new();
    Response::Metrics(Box::new(snap)).encode_line(&mut out);
    let body = String::from_utf8(out).unwrap();
    assert!(body.contains("\"ok\":true"), "{body}");
    assert!(body.contains("\"stage\":\"parse\""), "{body}");
    assert!(body.contains("\"stage\":\"warm_lookup\""), "{body}");
    assert!(body.contains("\"temp\":\"warm\""), "{body}");
    repro::util::Json::parse(body.trim()).expect("metrics reply must be valid JSON");
}
