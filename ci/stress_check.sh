#!/usr/bin/env bash
# Single-shot, hard-bounded run of the head-of-line stress test — shared
# by ci/check.sh and .github/workflows/ci.yml so the timeout, test name,
# and skip/drift detection can never diverge between the two CI paths.
#
# Fails when: the test fails, it stalls past the bound (a reintroduced
# engine stall), or the name filter matches nothing (test renamed).
# Prints an explicit note when the test self-skips because the PJRT
# backend is unavailable in this build, so a silent pass can't
# masquerade as coverage.
set -euo pipefail
cd "$(dirname "$0")/../rust"

out=$(timeout "${STRESS_TIMEOUT:-180}" cargo test --test server_integration \
    predicts_are_not_blocked_by_inflight_recommend_sweeps -- --nocapture 2>&1) \
    || { echo "$out"; echo "stress test FAILED (or stalled past the ${STRESS_TIMEOUT:-180}s bound)"; exit 1; }
echo "$out"
if echo "$out" | grep -q "running 0 tests"; then
    echo "stress-test filter matched nothing — was the test renamed?"
    exit 1
fi
if echo "$out" | grep -q "skipping server tests"; then
    echo "note: stress test SKIPPED (PJRT backend unavailable in this build)"
fi
