#!/usr/bin/env bash
# Single-shot, hard-bounded run of the chaos suite (tests/chaos.rs) —
# shared by ci/check.sh and .github/workflows/ci.yml so the timeout, the
# single-thread requirement, and skip/drift detection can never diverge
# between the two CI paths.
#
# The suite MUST run with --test-threads=1: the failpoint registry
# (util::failpoint) is process-global, and concurrent tests would see
# each other's armed points. Every test in the suite is `chaos_`-prefixed
# so check.sh's general `cargo test` sweep can exclude the whole binary's
# tests with one `--skip chaos_`.
#
# Fails when: any chaos test fails, the suite stalls past the bound (a
# wedged drain or supervisor loop under injected faults), or the name
# filter matches nothing (tests renamed away from the chaos_ prefix).
# Prints an explicit note when the suite self-skips because the PJRT
# backend is unavailable in this build, so a silent pass can't
# masquerade as coverage.
set -euo pipefail
cd "$(dirname "$0")/../rust"

# generous default bound: the suite trains a real model corpus once and
# runs an end-to-end onboard on top of the fault matrix
out=$(timeout "${CHAOS_TIMEOUT:-420}" cargo test --test chaos chaos_ -- --test-threads=1 --nocapture 2>&1) \
    || { echo "$out"; echo "chaos suite FAILED (or stalled past the ${CHAOS_TIMEOUT:-420}s bound)"; exit 1; }
echo "$out"
if echo "$out" | grep -q "running 0 tests"; then
    echo "chaos filter matched nothing — were the chaos_ tests renamed?"
    exit 1
fi
if echo "$out" | grep -q "skipping chaos tests"; then
    echo "note: chaos suite SKIPPED (PJRT backend unavailable in this build)"
fi
