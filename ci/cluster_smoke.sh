#!/usr/bin/env bash
# Cluster gate — shared by ci/check.sh and .github/workflows/ci.yml so
# the timeout and skip/drift rules can never diverge between the two CI
# paths. Two halves:
#
# 1. The deterministic cluster harness (tests/cluster.rs over the stub
#    backends in tests/cluster_util/): shard-routing-vs-ring oracle,
#    kill/failover/rejoin with hint replay, two-phase epoch agreement,
#    torn-snapshot invariants. Runtime-free (no PJRT, no model dir), so
#    this half ALWAYS runs — under a hard timeout, with a name-filter
#    guard so renaming the cluster_ tests can't silently empty the gate.
#
# 2. An end-to-end smoke: train a fast model dir, boot two real
#    `repro serve` backends plus a `repro route` front process, fire a
#    short `repro loadgen --targets` burst through the router, and check
#    the BENCH_serve.json `cluster` section plus a live `cluster_stats`
#    probe. Self-skips (loudly) when the PJRT backend is unavailable in
#    this build, same as loadgen_smoke.sh.
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cluster harness (deterministic, stub backends) =="
out=$(timeout "${CLUSTER_TIMEOUT:-240}" cargo test --test cluster cluster_ -- --nocapture 2>&1) \
    || { echo "$out"; echo "cluster harness FAILED (or stalled past the ${CLUSTER_TIMEOUT:-240}s bound)"; exit 1; }
echo "$out"
if echo "$out" | grep -q "running 0 tests"; then
    echo "cluster filter matched nothing — were the cluster_ tests renamed?"
    exit 1
fi

BIN=target/release/repro
[[ -x "$BIN" ]] || { echo "cluster smoke: $BIN missing — run cargo build --release first"; exit 1; }

tmp=$(mktemp -d "${TMPDIR:-/tmp}/repro_cluster_smoke.XXXXXX")
pids=()
cleanup() {
    for pid in "${pids[@]:-}"; do kill "$pid" 2>/dev/null || true; done
    rm -rf "$tmp"
}
trap cleanup EXIT

echo "== cluster smoke: training a fast model dir =="
if ! train_out=$("$BIN" train --fast true --out "$tmp/models" 2>&1); then
    echo "$train_out"
    if echo "$train_out" | grep -qi "pjrt\|runtime\|bindings"; then
        echo "note: cluster end-to-end smoke SKIPPED (PJRT backend unavailable in this build)"
        exit 0
    fi
    echo "cluster smoke: train failed for a non-runtime reason"
    exit 1
fi

# boot_addr <log> — wait for a "listening on <addr>" line, echo the addr
boot_addr() {
    local log=$1 pid=$2 addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/.*listening on \([0-9.:]*\).*/\1/p' "$log" | head -1)
        [[ -n "$addr" ]] && { echo "$addr"; return 0; }
        kill -0 "$pid" 2>/dev/null || { cat "$log" >&2; echo "process died during boot" >&2; return 1; }
        sleep 0.1
    done
    cat "$log" >&2; echo "process never printed its address" >&2; return 1
}

echo "== cluster smoke: booting two backends + the route tier =="
"$BIN" serve --addr 127.0.0.1:0 --models "$tmp/models" >"$tmp/serve_a.log" 2>&1 &
pids+=($!)
"$BIN" serve --addr 127.0.0.1:0 --models "$tmp/models" >"$tmp/serve_b.log" 2>&1 &
pids+=($!)
addr_a=$(boot_addr "$tmp/serve_a.log" "${pids[0]}")
addr_b=$(boot_addr "$tmp/serve_b.log" "${pids[1]}")
"$BIN" route --addr 127.0.0.1:0 --backends "$addr_a,$addr_b" \
    --probe-interval-ms 100 >"$tmp/route.log" 2>&1 &
pids+=($!)
router=$(boot_addr "$tmp/route.log" "${pids[2]}")
echo "backends on $addr_a + $addr_b, router on $router"

echo "== cluster smoke: open-loop burst through the router (--strict) =="
"$BIN" loadgen --addr "$router" --targets "$addr_a,$addr_b" \
    --rate 300 --duration 2 --conns 8 --predict-pct 80 \
    --out "$tmp/BENCH_serve.json" --strict

echo "== cluster smoke: artifact cluster section =="
for key in '"cluster"' '"backends"' '"throughput_rps"' '"share"' '"shard_skew"'; do
    grep -qF "$key" "$tmp/BENCH_serve.json" \
        || { echo "BENCH_serve.json missing $key"; cat "$tmp/BENCH_serve.json"; exit 1; }
done

echo "== cluster smoke: cluster_stats probe =="
stats=$(exec 3<>"/dev/tcp/${router%:*}/${router##*:}" \
    && printf '{"op":"cluster_stats"}\n' >&3 && head -n1 <&3 && exec 3<&- 3>&-)
echo "$stats" | grep -qF '"ok":true' \
    || { echo "cluster_stats op failed: $stats"; exit 1; }
echo "$stats" | grep -qF '"healthy_backends":2' \
    || { echo "router does not see both backends healthy: $stats"; exit 1; }
echo "cluster smoke: passed ($stats)"
