#!/usr/bin/env bash
# Loadgen smoke gate — boots a real server, fires a short open-loop burst
# with `repro loadgen --strict`, and fails on any dropped reply or a
# malformed BENCH_serve.json. Shared by ci/check.sh and
# .github/workflows/ci.yml (same skip/drift rules as stress_check.sh).
#
# Fails when: the burst drops a reply (graceful-drain/reactor regression),
# zero requests complete (server dead), the artifact is missing a schema
# key (including the v2 `server` section of server-side deltas), or the
# post-burst `metrics` op comes back with empty stage histograms (the
# observatory went blind). Prints an explicit SKIPPED note when the PJRT
# backend is unavailable in this build (training a model dir is
# impossible), so a silent pass can't masquerade as coverage.
set -euo pipefail
cd "$(dirname "$0")/../rust"

BIN=target/release/repro
[[ -x "$BIN" ]] || { echo "loadgen smoke: $BIN missing — run cargo build --release first"; exit 1; }

tmp=$(mktemp -d "${TMPDIR:-/tmp}/repro_loadgen_smoke.XXXXXX")
server_pid=""
cleanup() {
    [[ -n "$server_pid" ]] && kill "$server_pid" 2>/dev/null || true
    rm -rf "$tmp"
}
trap cleanup EXIT

echo "== loadgen smoke: training a fast model dir =="
if ! train_out=$("$BIN" train --fast true --out "$tmp/models" 2>&1); then
    echo "$train_out"
    if echo "$train_out" | grep -qi "pjrt\|runtime\|bindings"; then
        echo "note: loadgen smoke SKIPPED (PJRT backend unavailable in this build)"
        exit 0
    fi
    echo "loadgen smoke: train failed for a non-runtime reason"
    exit 1
fi

echo "== loadgen smoke: booting the server =="
"$BIN" serve --addr 127.0.0.1:0 --models "$tmp/models" >"$tmp/serve.log" 2>&1 &
server_pid=$!
addr=""
for _ in $(seq 1 100); do
    addr=$(sed -n 's/.*listening on \([0-9.:]*\).*/\1/p' "$tmp/serve.log" | head -1)
    [[ -n "$addr" ]] && break
    kill -0 "$server_pid" 2>/dev/null || { cat "$tmp/serve.log"; echo "server died during boot"; exit 1; }
    sleep 0.1
done
[[ -n "$addr" ]] || { cat "$tmp/serve.log"; echo "server never printed its address"; exit 1; }
echo "server up on $addr"

echo "== loadgen smoke: short open-loop burst (--strict) =="
"$BIN" loadgen --addr "$addr" --rate 300 --duration 2 --conns 8 \
    --predict-pct 80 --out "$tmp/BENCH_serve.json" --strict

echo "== loadgen smoke: artifact schema check =="
for key in '"schema":"profet.loadgen.v2"' '"p50"' '"p95"' '"p99"' '"p999"' \
           '"throughput_rps"' '"dropped"' '"overloaded"' '"per_op"' \
           '"server"' '"queue_wait_ms"' '"execute_ms"' '"cache_hit_ratio"'; do
    grep -qF "$key" "$tmp/BENCH_serve.json" \
        || { echo "BENCH_serve.json missing $key"; cat "$tmp/BENCH_serve.json"; exit 1; }
done

echo "== loadgen smoke: observatory check (metrics op after the burst) =="
# one-shot newline-delimited request over /dev/tcp; the server answers a
# line per request and holds the connection open, so read exactly one
metrics=$(exec 3<>"/dev/tcp/${addr%:*}/${addr##*:}" \
    && printf '{"op":"metrics"}\n' >&3 && head -n1 <&3 && exec 3<&- 3>&-)
echo "$metrics" | grep -qF '"ok":true' \
    || { echo "metrics op failed: $metrics"; exit 1; }
# the burst just pushed hundreds of requests through every stage — an
# empty histogram here means the instrumentation fell off the hot path
for stage in '"stage":"parse"' '"stage":"queue_wait"' '"stage":"execute"' \
             '"stage":"write_flush"'; do
    echo "$metrics" | grep -qF "$stage" \
        || { echo "metrics reply missing populated $stage histogram"; echo "$metrics" | head -c 2000; exit 1; }
done
echo "metrics op: per-stage histograms populated"

# publish for the workflow's artifact upload step (repo root)
cp "$tmp/BENCH_serve.json" ../BENCH_serve.json
echo "loadgen smoke: passed (artifact at BENCH_serve.json)"
