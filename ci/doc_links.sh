#!/usr/bin/env bash
# Relative-link check over the markdown docs: every [text](path) whose
# target is not an URL or a pure anchor must point at an existing file
# (anchors after '#' are stripped; paths resolve relative to the file
# containing the link). Keeps docs/*.md and README.md from silently
# rotting as files move.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
for md in README.md ROADMAP.md docs/*.md; do
    [[ -f "$md" ]] || continue
    dir=$(dirname "$md")
    # extract every inline-link destination
    while IFS= read -r target; do
        case "$target" in
            http://*|https://*|mailto:*|\#*) continue ;;
        esac
        path="${target%%#*}"
        [[ -n "$path" ]] || continue
        if [[ ! -e "$dir/$path" ]]; then
            echo "BROKEN LINK: $md -> $target"
            fail=1
        fi
    done < <(grep -oE '\]\(([^)]+)\)' "$md" | sed -E 's/^\]\(//; s/\)$//')
done

if [[ "$fail" != 0 ]]; then
    echo "doc_links.sh: broken relative links found"
    exit 1
fi
echo "doc_links.sh: all relative doc links resolve"
