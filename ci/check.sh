#!/usr/bin/env bash
# Tier-1 + hygiene gate for the rust crate. Mirrors .github/workflows/ci.yml
# so the same command runs locally and in CI:
#
#   ./ci/check.sh            # build (lib + examples) + test + fmt + clippy
#   ./ci/check.sh --bench    # additionally run the hot_paths bench and
#                            # refresh BENCH_hot_paths.json (BENCH_SMOKE=1
#                            # for the short-iteration CI variant)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== cargo test -q =="
cargo test -q

# advisory until the pre-existing tree is formatted/lint-clean (the seed
# predates rustfmt/clippy enforcement); set CI_STRICT=1 to make them gate
echo "== cargo fmt --check =="
cargo fmt --check || [[ "${CI_STRICT:-}" != "1" ]]

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings || [[ "${CI_STRICT:-}" != "1" ]]

if [[ "${1:-}" == "--bench" ]]; then
    echo "== cargo bench --bench hot_paths =="
    cargo bench --bench hot_paths
fi

echo "ci/check.sh: all gates passed"
