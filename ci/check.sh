#!/usr/bin/env bash
# Tier-1 + hygiene gate for the rust crate. Mirrors .github/workflows/ci.yml
# so the same command runs locally and in CI:
#
#   ./ci/check.sh            # build (lib + examples) + test + fmt + clippy
#   ./ci/check.sh --bench    # additionally run the hot_paths bench and
#                            # refresh BENCH_hot_paths.json (BENCH_SMOKE=1
#                            # for the short-iteration CI variant)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== build tests (dev profile) =="
cargo test -q --no-run

# the head-of-line stress test runs single-shot under a hard timeout
# FIRST: if an engine stall is ever reintroduced (predicts queueing
# behind a recommend sweep), this fails fast instead of hanging the
# whole `cargo test` invocation below (shared logic: ci/stress_check.sh)
echo "== server stress test (single-shot, bounded) =="
../ci/stress_check.sh   # (cwd is rust/ after the cd above)

# counting-allocator gate, single-shot in its own test binary (a
# #[global_allocator] is per-binary): zero wire-layer allocations on a
# warm predict round trip, or this fails loudly
echo "== wire allocation gate (counting allocator) =="
cargo test -q --test wire_alloc

echo "== cargo test -q (stress + chaos excluded — they run single-shot) =="
cargo test -q -- --skip predicts_are_not_blocked_by_inflight_recommend_sweeps --skip chaos_

# fault-injection suite, single-shot under a hard timeout and forced to
# one test thread (the failpoint registry is process-global): save-crash
# matrix, torn staging tails, panicking replicas, reactor write faults,
# watcher faults, deadline shedding (shared logic: ci/chaos_check.sh)
echo "== chaos suite (failpoint injection, bounded, single-threaded) =="
../ci/chaos_check.sh

# boots a real server and fires a short strict open-loop burst: any
# dropped reply or malformed BENCH_serve.json fails; self-skips (loudly)
# when the PJRT backend is unavailable (shared logic: ci/loadgen_smoke.sh)
echo "== loadgen smoke (server boot + strict burst) =="
../ci/loadgen_smoke.sh

# the deterministic cluster harness (stub backends, hard timeout) plus a
# router + two-backend end-to-end burst; the end-to-end half self-skips
# when PJRT is unavailable (shared logic: ci/cluster_smoke.sh)
echo "== cluster gate (harness + route-tier smoke) =="
../ci/cluster_smoke.sh

# invariant linter, hard gate: hot-path allocations, reactor blocking
# calls, unsafe/atomic hygiene, protocol doc drift — findings name the
# exact file:line and rule (see docs/ANALYSIS.md for the catalogue and
# the allowlist syntax)
echo "== repro lint (static analysis) =="
target/release/repro lint

# rustdoc gate: module docs, doc-examples, and intra-doc links must stay
# warning-clean (broken links rot silently otherwise)
echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

# markdown docs: every relative link in README/docs must resolve
echo "== docs/*.md relative-link check =="
../ci/doc_links.sh

# advisory until the pre-existing tree is formatted/lint-clean (the seed
# predates rustfmt/clippy enforcement); set CI_STRICT=1 to make them gate
echo "== cargo fmt --check =="
cargo fmt --check || [[ "${CI_STRICT:-}" != "1" ]]

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings || [[ "${CI_STRICT:-}" != "1" ]]

if [[ "${1:-}" == "--bench" ]]; then
    echo "== cargo bench --bench hot_paths =="
    cargo bench --bench hot_paths
fi

echo "ci/check.sh: all gates passed"
