//! New-GPU onboarding: the Table VI / Sec V-E scenario.
//!
//! A cloud vendor releases a new GPU instance (AWS G5 / A10, or a
//! different vendor's P100). The vendor — who controls the hardware before
//! customers see it — runs the offline corpus on the new device, trains
//! anchor→new-target models, and can then serve predictions for customer
//! workloads profiled on any OLD instance.
//!
//! Run: `cargo run --release --example new_gpu_onboarding`

use repro::data::Corpus;
use repro::gpu::Instance;
use repro::ml::metrics;
use repro::predictor::{Profet, TrainOptions};

fn main() -> repro::Result<()> {
    let rt = repro::runtime::load_default()?;
    println!("vendor-side onboarding of {:?} ...", Instance::NEW);
    let corpus = Corpus::generate(&Instance::ALL);
    let (train_idx, test_idx) = corpus.split_random(0.2, 3);

    let opts = TrainOptions {
        anchors: Instance::CORE.to_vec(),
        targets: Instance::NEW.to_vec(),
        n_trees: 40,
        dnn_epochs: 25,
        ..Default::default()
    };
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts)?;
    println!("trained {} anchor->new-GPU ensembles\n", profet.cross.len());

    println!("{:16} {:>10} {:>10} {:>8}", "anchor -> new", "n", "MAPE %", "R2");
    for t in Instance::NEW {
        for a in Instance::CORE {
            let mut truth = Vec::new();
            let mut pred = Vec::new();
            for &i in &test_idx {
                let e = &corpus.entries[i];
                let (Some(ar), Some(tr)) = (e.runs.get(&a), e.runs.get(&t)) else {
                    continue;
                };
                let (p, _) = profet.predict_cross(&rt, a, t, &ar.profile, ar.latency_ms)?;
                truth.push(tr.latency_ms);
                pred.push(p);
            }
            println!(
                "{:16} {:>10} {:>10.2} {:>8.3}",
                format!("{} -> {}", a.key(), t.spec().gpu_model),
                truth.len(),
                metrics::mape(&truth, &pred),
                metrics::r2(&truth, &pred)
            );
        }
    }
    println!("\nCustomers profiled on old instances can now be quoted for the new hardware");
    println!("before migrating — no customer-side reruns required (paper Sec III-C3).");
    Ok(())
}
