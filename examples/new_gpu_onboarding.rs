//! New-GPU onboarding, **online** (Table VI / Sec V-E, served live).
//!
//! A cloud vendor releases a new GPU instance (AWS G5 / A10). The old
//! workflow retrained offline and restarted the service; this example
//! drives the live path end to end against a running server:
//!
//! 1. boot the PROFET service with models that know nothing about G5;
//! 2. `predict` g4dn→g5 — a structured "no model" error;
//! 3. stream the vendor's profiled measurements in as `ingest` lines;
//! 4. `onboard` — the trainer lane fits the g4dn→g5 ensemble (frozen
//!    feature space), validates it, and publishes registry epoch 2
//!    WITHOUT interrupting service;
//! 5. `predict` g4dn→g5 now answers, quoted against simulator truth;
//! 6. `stats` shows the bumped `registry_epoch` / `last_reload`.
//!
//! Run: `cargo run --release --example new_gpu_onboarding`

use repro::coordinator::{self, ServeOptions};
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::ml::metrics;
use repro::predictor::{Profet, TrainOptions};
use repro::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn send(addr: std::net::SocketAddr, line: &str) -> repro::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Json::parse(resp.trim())
}

fn predict_line(profile: &std::collections::BTreeMap<String, f64>, lat: f64) -> String {
    let mut req = Json::obj();
    req.set("op", Json::Str("predict".into()));
    req.set("anchor", Json::Str("g4dn".into()));
    req.set("target", Json::Str("g5".into()));
    req.set("anchor_latency_ms", Json::Num(lat));
    let mut prof = Json::obj();
    for (k, v) in profile {
        prof.set(k, Json::Num(*v));
    }
    req.set("profile", prof);
    req.to_string()
}

fn main() -> repro::Result<()> {
    let anchor = Instance::G4dn;
    let new_gpu = Instance::G5;

    // ---- vendor-side data: the offline corpus incl. the new device ----
    println!("generating corpus (incl. the new {new_gpu} device) ...");
    let corpus = Corpus::generate(&[anchor, Instance::P3, new_gpu]);
    let (train_idx, test_idx) = corpus.split_random(0.2, 3);

    // ---- 1. boot the service on models that predate the new GPU -------
    let rt = repro::runtime::load_default()?;
    let opts = TrainOptions {
        anchors: vec![anchor],
        targets: vec![Instance::P3],
        n_trees: 40,
        dnn_epochs: 25,
        ..Default::default()
    };
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts)?;
    let model_dir = std::env::temp_dir().join("repro_onboarding_models");
    std::fs::remove_dir_all(&model_dir).ok();
    profet.save(&model_dir)?;
    drop(profet);
    drop(rt); // the service owns its own runtimes from here on

    let handle = coordinator::serve_with(
        "127.0.0.1:0",
        repro::runtime::default_artifact_dir(),
        model_dir.clone(),
        &ServeOptions::default(),
    )?;
    let addr = handle.addr;
    println!("service up on {addr} (epoch 1, targets: p3 only)\n");

    // ---- 2. the new pair is not served yet ----------------------------
    let sample = corpus
        .entries
        .iter()
        .find(|e| e.runs.contains_key(&anchor) && e.runs.contains_key(&new_gpu))
        .expect("corpus has paired runs");
    let a_run = &sample.runs[&anchor];
    let before = send(addr, &predict_line(&a_run.profile, a_run.latency_ms))?;
    assert_eq!(before.get("ok").and_then(Json::as_bool), Some(false));
    println!(
        "predict g4dn->g5 before onboarding: {}",
        before.req_str("error").unwrap_or("?")
    );

    // ---- 3. ingest the vendor's profiled measurements -----------------
    let mut staged = 0usize;
    for &i in &train_idx {
        let e = &corpus.entries[i];
        let (Some(ar), Some(tr)) = (e.runs.get(&anchor), e.runs.get(&new_gpu)) else {
            continue;
        };
        let mut req = Json::obj();
        req.set("op", Json::Str("ingest".into()));
        req.set("anchor", Json::Str(anchor.key().into()));
        req.set("target", Json::Str(new_gpu.key().into()));
        req.set("model", Json::Str(e.workload.model.name().into()));
        req.set("batch", Json::Num(e.workload.batch as f64));
        req.set("pixels", Json::Num(e.workload.pixels as f64));
        let mut prof = Json::obj();
        for (k, v) in &ar.profile {
            prof.set(k, Json::Num(*v));
        }
        req.set("profile", prof);
        req.set("anchor_latency_ms", Json::Num(ar.latency_ms));
        req.set("target_latency_ms", Json::Num(tr.latency_ms));
        let resp = send(addr, &req.to_string())?;
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        staged = resp.req_f64("staged")? as usize;
    }
    println!("ingested {staged} g4dn->g5 measurements into the staging area");

    // ---- 4. onboard: train + validate + publish, live -----------------
    let t0 = std::time::Instant::now();
    let ob = send(addr, r#"{"op":"onboard","anchor":"g4dn","target":"g5"}"#)?;
    assert_eq!(ob.get("ok").and_then(Json::as_bool), Some(true), "{ob:?}");
    println!(
        "onboarded in {:.1}s -> registry epoch {} ({} pair, {} measurements)\n",
        t0.elapsed().as_secs_f64(),
        ob.req_f64("epoch")?,
        ob.req_f64("pairs")?,
        ob.req_f64("staged")?
    );

    // ---- 5. the new pair serves; quote it against simulator truth -----
    let mut truth = Vec::new();
    let mut pred = Vec::new();
    for &i in &test_idx {
        let e = &corpus.entries[i];
        let (Some(ar), Some(tr)) = (e.runs.get(&anchor), e.runs.get(&new_gpu)) else {
            continue;
        };
        let resp = send(addr, &predict_line(&ar.profile, ar.latency_ms))?;
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        truth.push(tr.latency_ms);
        pred.push(resp.req_f64("latency_ms")?);
    }
    println!(
        "{:16} {:>10} {:>10} {:>8}",
        "anchor -> new", "n", "MAPE %", "R2"
    );
    println!(
        "{:16} {:>10} {:>10.2} {:>8.3}",
        format!("{} -> {}", anchor.key(), new_gpu.spec().gpu_model),
        truth.len(),
        metrics::mape(&truth, &pred),
        metrics::r2(&truth, &pred)
    );

    // ---- 6. stats carry the registry state ----------------------------
    let st = send(addr, r#"{"op":"stats"}"#)?;
    println!(
        "\nstats: registry_epoch={} last_reload={} requests={}",
        st.req_f64("registry_epoch")?,
        st.req_f64("last_reload")?,
        st.req_f64("requests")?
    );
    println!("\nCustomers profiled on old instances can now be quoted for the new hardware");
    println!("without the service ever going down (paper Sec III-C3, served live).");
    handle.stop();
    std::fs::remove_dir_all(&model_dir).ok();
    Ok(())
}
