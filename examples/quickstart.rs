//! Quickstart: the complete PROFET flow in one file.
//!
//! 1. Generate the offline experiment corpus (simulator substitute for the
//!    paper's EC2 runs).
//! 2. Train the PROFET system (feature clustering + median ensemble with
//!    the HLO-compiled DNN + batch/pixel polynomials).
//! 3. Profile a "new" workload on an anchor instance and predict its
//!    training latency on a target instance, comparing with ground truth.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use repro::data::Corpus;
use repro::gpu::Instance;
use repro::models::ModelId;
use repro::predictor::{Profet, TrainOptions};
use repro::sim::{self, Workload};

fn main() -> repro::Result<()> {
    // L2/L1 build products: the AOT-compiled HLO artifacts.
    let rt = repro::runtime::load_default()?;
    println!("PJRT backend: {}", rt.platform());

    // 1. offline corpus (every executable G x M x B x P case)
    println!("generating corpus ...");
    let corpus = Corpus::generate(&Instance::CORE);
    println!(
        "  {} workloads / {} observations / {} distinct ops",
        corpus.entries.len(),
        corpus.n_observations(),
        corpus.vocabulary().len()
    );

    // 2. train (reduced hyper-parameters so the example runs in seconds)
    let (train_idx, _) = corpus.split_random(0.2, 1);
    let opts = TrainOptions {
        anchors: vec![Instance::G4dn],
        targets: Instance::CORE.to_vec(),
        n_trees: 30,
        dnn_epochs: 20,
        ..Default::default()
    };
    println!("training PROFET (anchor g4dn -> all targets) ...");
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts)?;
    println!(
        "  {} cross-instance ensembles, {} live features",
        profet.cross.len(),
        profet.feature_space.n_features()
    );

    // 3. the client story (Fig 3): profile on the anchor, predict elsewhere
    let workload = Workload::new(ModelId::ResNet50, 32, 128);
    let anchor = Instance::G4dn;
    let run = sim::run_workload(&workload, anchor).expect("executable");
    println!(
        "\nprofiled {} on {}: {:.1} ms/batch, {} distinct ops",
        workload.key(),
        anchor,
        run.latency_ms,
        run.profile.aggregated().len()
    );
    println!("predicted training latency elsewhere:");
    for target in Instance::CORE {
        if target == anchor {
            continue;
        }
        let (pred, member) = profet.predict_cross(
            &rt,
            anchor,
            target,
            &run.profile.aggregated(),
            run.latency_ms,
        )?;
        let truth = sim::run_workload(&workload, target).unwrap().latency_ms;
        println!(
            "  {:5} pred {:8.1} ms   truth {:8.1} ms   APE {:5.1}%   (median from {})",
            target.key(),
            pred,
            truth,
            100.0 * (pred - truth).abs() / truth,
            member.name()
        );
    }

    // bonus: phase-2 — what if the batch size changes?
    let r16 = sim::run_workload(&Workload::new(ModelId::ResNet50, 16, 128), anchor).unwrap();
    let r256 = sim::run_workload(&Workload::new(ModelId::ResNet50, 256, 128), anchor).unwrap();
    let p64 = profet.predict_scenario(
        &rt,
        anchor,
        Instance::P3,
        &r16.profile.aggregated(),
        r16.latency_ms,
        &r256.profile.aggregated(),
        r256.latency_ms,
        64,
    )?;
    let t64 = sim::run_workload(&Workload::new(ModelId::ResNet50, 64, 128), Instance::P3)
        .unwrap()
        .latency_ms;
    println!(
        "\ntwo-phase scenario: ResNet50@128px b=64 on p3: pred {:.1} ms, truth {:.1} ms (APE {:.1}%)",
        p64,
        t64,
        100.0 * (p64 - t64).abs() / t64
    );
    Ok(())
}
