//! END-TO-END DRIVER: the full three-layer system on a real serving
//! workload, proving all layers compose (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Pipeline exercised, Python nowhere on the path:
//!   1. corpus generation (simulator substrate),
//!   2. PROFET training — the DNN member trains by driving the AOT-compiled
//!      JAX/Pallas train-step artifact through PJRT (L2/L1),
//!   3. model persistence to a registry directory,
//!   4. the TCP/JSON coordinator (L3): router + dynamic batcher over the
//!      fixed-shape MLP forward artifact,
//!   5. a closed-loop client fleet issuing profiled-workload prediction
//!      requests; reports throughput, latency percentiles, batching stats,
//!      and prediction accuracy against simulator ground truth.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

use repro::coordinator;
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::predictor::{Profet, TrainOptions};
 
use repro::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

fn main() -> repro::Result<()> {
    // ---- 1. corpus ------------------------------------------------------
    let t0 = Instant::now();
    let rt = repro::runtime::load_default()?;
    let corpus = Corpus::generate(&Instance::CORE);
    println!(
        "[{:6.1?}] corpus: {} workloads / {} observations",
        t0.elapsed(),
        corpus.entries.len(),
        corpus.n_observations()
    );

    // ---- 2. train (DNN member = HLO train-step loop over PJRT) ----------
    let (train_idx, test_idx) = corpus.split_random(0.2, 4);
    let opts = TrainOptions {
        anchors: vec![Instance::G4dn],
        targets: Instance::CORE.to_vec(),
        n_trees: 40,
        dnn_epochs: 25,
        ..Default::default()
    };
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts)?;
    println!(
        "[{:6.1?}] trained {} ensembles ({} features)",
        t0.elapsed(),
        profet.cross.len(),
        profet.feature_space.n_features()
    );

    // ---- 3. persist -----------------------------------------------------
    let model_dir = std::env::temp_dir().join("repro_serve_e2e_models");
    std::fs::remove_dir_all(&model_dir).ok();
    profet.save(&model_dir)?;
    println!("[{:6.1?}] models saved to {}", t0.elapsed(), model_dir.display());

    // ---- 4. serve -------------------------------------------------------
    let handle = coordinator::serve(
        "127.0.0.1:0",
        repro::runtime::default_artifact_dir(),
        model_dir.clone(),
    )?;
    let addr = handle.addr;
    println!("[{:6.1?}] coordinator listening on {addr}", t0.elapsed());

    // ---- 5. client fleet -------------------------------------------------
    // request payloads: held-out workloads profiled on the anchor
    let mut payloads = Vec::new();
    for &i in &test_idx {
        let e = &corpus.entries[i];
        let Some(a) = e.runs.get(&Instance::G4dn) else { continue };
        for target in [Instance::P3, Instance::P2, Instance::G3s] {
            let Some(t) = e.runs.get(&target) else { continue };
            let mut profile = Json::obj();
            for (k, v) in &a.profile {
                profile.set(k, Json::Num(*v));
            }
            let mut req = Json::obj();
            req.set("op", Json::Str("predict".into()));
            req.set("anchor", Json::Str("g4dn".into()));
            req.set("target", Json::Str(target.key().into()));
            req.set("anchor_latency_ms", Json::Num(a.latency_ms));
            req.set("profile", profile);
            payloads.push((req.to_string(), t.latency_ms));
        }
    }
    println!(
        "[{:6.1?}] client fleet: {} requests across 16 connections",
        t0.elapsed(),
        payloads.len()
    );

    let clients = 16usize;
    let t_serve = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let slice: Vec<(String, f64)> = payloads
            .iter()
            .skip(c)
            .step_by(clients)
            .cloned()
            .collect();
        joins.push(std::thread::spawn(move || -> (Vec<f64>, Vec<f64>) {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let mut rtts = Vec::new();
            let mut apes = Vec::new();
            for (line, truth) in slice {
                let t = Instant::now();
                writer.write_all(line.as_bytes()).unwrap();
                writer.write_all(b"\n").unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                rtts.push(t.elapsed().as_secs_f64() * 1e3);
                let j = Json::parse(resp.trim()).unwrap();
                assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
                let pred = j.req_f64("latency_ms").unwrap();
                apes.push(100.0 * (pred - truth).abs() / truth);
            }
            (rtts, apes)
        }));
    }
    let mut rtts = Vec::new();
    let mut apes = Vec::new();
    for j in joins {
        let (r, a) = j.join().unwrap();
        rtts.extend(r);
        apes.extend(a);
    }
    let wall = t_serve.elapsed().as_secs_f64();
    let thr = rtts.len() as f64 / wall;

    println!("\n=== serve_e2e results ===");
    println!("requests      : {}", rtts.len());
    println!("wall time     : {wall:.2} s");
    println!("throughput    : {thr:.0} req/s");
    println!(
        "latency ms    : p50={:.2}  p90={:.2}  p99={:.2}  max={:.2}",
        repro::util::quantile(&rtts, 0.50),
        repro::util::quantile(&rtts, 0.90),
        repro::util::quantile(&rtts, 0.99),
        repro::util::quantile(&rtts, 1.0)
    );
    println!(
        "accuracy      : MAPE {:.2}%  (p90 APE {:.1}%)",
        repro::util::mean(&apes),
        repro::util::quantile(&apes, 0.90)
    );
    let served = handle.stats.requests.load(std::sync::atomic::Ordering::Relaxed);
    let batches = handle.stats.batches.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "service totals: {served} requests in {batches} artifact batches (avg {:.1} req/exec)",
        served as f64 / batches.max(1) as f64
    );
    assert!(batches < served, "dynamic batching must coalesce requests");

    assert!(repro::util::mean(&apes) < 25.0, "serving accuracy degraded");
    handle.stop();
    std::fs::remove_dir_all(&model_dir).ok();
    println!("\nE2E driver complete: all three layers composed.");
    Ok(())
}
