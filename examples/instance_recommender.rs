//! Instance recommender: the paper's motivating use case (Sec II / Fig 2).
//!
//! A CNN developer has a workload and an anchor instance. PROFET predicts
//! the mini-batch latency on every available GPU instance; combined with
//! on-demand pricing this yields a latency/cost Pareto recommendation —
//! without ever running the workload anywhere but the anchor.
//!
//! Run: `cargo run --release --example instance_recommender [Model] [batch] [pixels]`

use repro::data::Corpus;
use repro::gpu::Instance;
use repro::models::ModelId;
use repro::predictor::{Profet, TrainOptions};
use repro::sim::{self, Workload};

fn main() -> repro::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .first()
        .and_then(|s| ModelId::from_name(s))
        .unwrap_or(ModelId::MobileNetV2);
    let batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let pixels: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);

    let rt = repro::runtime::load_default()?;
    println!("training PROFET across all six instances ...");
    let corpus = Corpus::generate(&Instance::ALL);
    let (train_idx, _) = corpus.split_random(0.2, 2);
    let opts = TrainOptions {
        anchors: vec![Instance::G4dn],
        targets: Instance::ALL.to_vec(),
        n_trees: 40,
        dnn_epochs: 25,
        ..Default::default()
    };
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts)?;

    let anchor = Instance::G4dn;
    let w = Workload::new(model, batch, pixels);
    let run = sim::run_workload(&w, anchor).expect("workload must run on the anchor");
    println!(
        "\nworkload {} profiled on {} ({:.1} ms/batch)\n",
        w.key(),
        anchor,
        run.latency_ms
    );
    println!(
        "{:6} {:>12} {:>12} {:>14} {:>10}",
        "inst", "pred ms", "truth ms", "$ / 10k batches", "verdict"
    );

    let mut rows = Vec::new();
    for target in Instance::ALL {
        let pred_ms = if target == anchor {
            run.latency_ms
        } else {
            profet
                .predict_cross(&rt, anchor, target, &run.profile.aggregated(), run.latency_ms)?
                .0
        };
        let truth = sim::run_workload(&w, target).map(|r| r.latency_ms);
        let cost = pred_ms / 3.6e6 * target.spec().price_hr * 10_000.0;
        rows.push((target, pred_ms, truth, cost));
    }
    let fastest = rows
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0;
    let cheapest = rows
        .iter()
        .min_by(|a, b| a.3.partial_cmp(&b.3).unwrap())
        .unwrap()
        .0;
    for (inst, pred, truth, cost) in &rows {
        let verdict = match (inst == &fastest, inst == &cheapest) {
            (true, true) => "fast+cheap",
            (true, false) => "fastest",
            (false, true) => "cheapest",
            _ => "",
        };
        println!(
            "{:6} {:>12.1} {:>12} {:>14.3} {:>10}",
            inst.key(),
            pred,
            truth.map(|t| format!("{t:.1}")).unwrap_or_else(|| "OOM".into()),
            cost,
            verdict
        );
    }
    println!(
        "\nrecommendation: train on {} for speed, {} for cost.",
        fastest.key(),
        cheapest.key()
    );
    Ok(())
}
