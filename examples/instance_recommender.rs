//! Instance recommender: the paper's motivating use case (Sec II / Fig 2),
//! served by the `advisor` subsystem end to end.
//!
//! A CNN developer profiles a workload ONCE on an anchor instance (at the
//! min/max batch and pixel endpoints). The advisor sweeps every (target
//! instance × batch × pixel × GPU count × pricing) candidate through
//! phase-1 cross-instance prediction + the batch/pixel interpolators,
//! computes the cost-latency Pareto frontier, and answers constrained
//! planning queries — without ever running the workload anywhere but the
//! anchor.
//!
//! Run: `cargo run --release --example instance_recommender [Model] [batch] [pixels]`

use repro::advisor::{self, CacheStats, EndpointProfiles, Objective, PredictionCache, SweepRequest, TrainingJob};
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::models::ModelId;
use repro::predictor::{Profet, TrainOptions};
use repro::sim::{self, ScalingTable, Workload, BATCHES, PIXELS};

fn endpoint_profiles(anchor: Instance, lo: Workload, hi: Workload) -> Option<EndpointProfiles> {
    let run_lo = sim::run_workload(&lo, anchor)?;
    let run_hi = sim::run_workload(&hi, anchor)?;
    Some(EndpointProfiles {
        profile_min: run_lo.profile.aggregated(),
        lat_min: run_lo.latency_ms,
        profile_max: run_hi.profile.aggregated(),
        lat_max: run_hi.latency_ms,
    })
}

fn main() -> repro::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .first()
        .and_then(|s| ModelId::from_name(s))
        .unwrap_or(ModelId::MobileNetV2);
    let batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let pixels: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    // stay inside the interpolation models' fitted grid — beyond it the
    // batch/pixel polynomials would extrapolate, exactly what the serving
    // layer rejects
    anyhow::ensure!(
        (BATCHES[0]..=BATCHES[4]).contains(&batch),
        "batch {batch} outside the modeled range [{}, {}]",
        BATCHES[0],
        BATCHES[4]
    );
    anyhow::ensure!(
        (PIXELS[0]..=PIXELS[4]).contains(&pixels),
        "pixels {pixels} outside the modeled range [{}, {}]",
        PIXELS[0],
        PIXELS[4]
    );

    let rt = repro::runtime::load_default()?;
    println!("training PROFET across all six instances ...");
    let corpus = Corpus::generate(&Instance::ALL);
    let (train_idx, _) = corpus.split_random(0.2, 2);
    let opts = TrainOptions {
        anchors: vec![Instance::G4dn],
        targets: Instance::ALL.to_vec(),
        n_trees: 40,
        dnn_epochs: 25,
        ..Default::default()
    };
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts)?;

    // ---- profile the workload on the anchor, endpoints only ------------
    let anchor = Instance::G4dn;
    let Some(batch_ep) = endpoint_profiles(
        anchor,
        Workload::new(model, BATCHES[0], pixels),
        Workload::new(model, BATCHES[4], pixels),
    ) else {
        anyhow::bail!(
            "{} at {}px cannot run at the b={}/b={} batch endpoints on {} \
             (model constraint or OOM) — try a smaller pixel size",
            model.name(),
            pixels,
            BATCHES[0],
            BATCHES[4],
            anchor
        );
    };
    println!(
        "\n{} profiled on {} at the batch endpoints (b{}: {:.1} ms, b{}: {:.1} ms)",
        model.name(),
        anchor,
        BATCHES[0],
        batch_ep.lat_min,
        BATCHES[4],
        batch_ep.lat_max,
    );

    // ---- sweep the full candidate grid ---------------------------------
    // (pixel endpoints are omitted: this sweep stays at the asked pixel
    // size — pass them plus `pixel_sizes` to sweep the resolution axis)
    let query = SweepRequest {
        anchor,
        pixels,
        batch: batch_ep,
        pixel: None,
        targets: Vec::new(),            // anchor + every modeled target
        batches: vec![batch],           // compare instances at the asked batch
        pixel_sizes: Vec::new(),        // at the asked pixel size
        gpu_counts: vec![1, 2, 4],
        include_spot: true,
    };
    let cache = PredictionCache::new(16, 4096);
    let cache_stats = CacheStats::default();
    let scaling = ScalingTable::new();
    let cands = advisor::sweep(&rt, 0, &profet, &cache, &cache_stats, &scaling, &query)?;
    assert!(!cands.is_empty(), "sweep produced no candidates");

    let points: Vec<(f64, f64)> = cands.iter().map(|c| c.objectives()).collect();
    let frontier: std::collections::BTreeSet<usize> =
        advisor::pareto_frontier(&points).into_iter().collect();

    let order = advisor::rank_candidates(&cands);
    println!(
        "\n{:6} {:>5} {:>10} {:>12} {:>12} {:>9} {:>14} {:>9}",
        "inst", "gpus", "pricing", "step ms", "imgs/s", "$/hr", "$/1M imgs", "frontier"
    );
    // show the cheapest 16 rows, plus every frontier point regardless
    let shown: Vec<usize> = order
        .iter()
        .enumerate()
        .filter(|&(rank, i)| rank < 16 || frontier.contains(i))
        .map(|(_, &i)| i)
        .collect();
    for &i in &shown {
        let c = &cands[i];
        println!(
            "{:6} {:>5} {:>10} {:>12.1} {:>12.0} {:>9.3} {:>14.3} {:>9}",
            c.target.key(),
            c.n_gpus,
            c.pricing.key(),
            c.latency_ms,
            c.imgs_per_s,
            c.price_hr,
            c.cost_per_img_usd * 1e6,
            if frontier.contains(&i) { "*" } else { "" }
        );
    }
    if shown.len() < cands.len() {
        println!("  ... (+{} dominated candidates not shown)", cands.len() - shown.len());
    }
    println!(
        "\n{} candidates, {} on the Pareto frontier; phase-1 cache: {} hits / {} misses",
        cands.len(),
        frontier.len(),
        cache_stats.hits.load(std::sync::atomic::Ordering::Relaxed),
        cache_stats.misses.load(std::sync::atomic::Ordering::Relaxed),
    );

    // ---- constrained planning ------------------------------------------
    let job = TrainingJob {
        dataset_images: 1_281_167.0, // ImageNet-1k
        epochs: 90.0,
    };
    for (label, objective) in [
        (
            "cheapest finishing within 72 h",
            Objective::CheapestUnderDeadline { deadline_hours: 72.0 },
        ),
        (
            "fastest within a $200 budget",
            Objective::FastestUnderBudget { budget_usd: 200.0 },
        ),
        (
            "most epochs within 24 h",
            Objective::MaxEpochsUnderDeadline { deadline_hours: 24.0 },
        ),
    ] {
        match advisor::plan(&cands, &job, &objective) {
            Some(p) => {
                let c = &cands[p.index];
                println!(
                    "plan [{label}]: {} x{} ({}) — {:.1} h, ${:.2}, {:.0} epochs",
                    c.target.key(),
                    c.n_gpus,
                    c.pricing.key(),
                    p.hours,
                    p.cost_usd,
                    p.epochs
                );
            }
            None => println!("plan [{label}]: infeasible on every candidate"),
        }
    }
    Ok(())
}
