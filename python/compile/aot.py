"""AOT compile path: lower L2/L1 jax functions to HLO *text* artifacts.

HLO text (NOT lowered.compiler_ir(...).serialize()) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifacts (fixed shapes recorded in artifacts/meta.json):
  mlp_fwd.hlo.txt    (params[P], x[B,D])            -> (yhat[B],)
  mlp_train.hlo.txt  (params,m,v [P], t[], x[Bt,D], y[Bt])
                                                    -> (p',m',v',t',loss)
  levenshtein.hlo.txt (a[K,L], b[K,L], la[K], lb[K]) -> (dist[K],)

Run: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import levenshtein as lev_kernel
from .kernels import ref

# Fixed AOT shapes. D_FEAT must match rust/src (feature space padded to this
# width); B_PRED is the serving batch, B_TRAIN the training minibatch.
D_FEAT = 48
B_PRED = 64
B_TRAIN = 32
LEV_K = 64
LEV_L = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    p = ref.mlp_param_count(D_FEAT)
    f32 = jnp.float32
    i32 = jnp.int32
    s = jax.ShapeDtypeStruct

    fwd = jax.jit(model.predict_batch).lower(s((p,), f32), s((B_PRED, D_FEAT), f32))
    train = jax.jit(model.train_step_entry).lower(
        s((p,), f32),
        s((p,), f32),
        s((p,), f32),
        s((), f32),
        s((B_TRAIN, D_FEAT), f32),
        s((B_TRAIN,), f32),
    )
    lev = jax.jit(lambda a, b, la, lb: (lev_kernel.levenshtein(a, b, la, lb),)).lower(
        s((LEV_K, LEV_L), i32),
        s((LEV_K, LEV_L), i32),
        s((LEV_K,), i32),
        s((LEV_K,), i32),
    )
    return {"mlp_fwd": fwd, "mlp_train": train, "levenshtein": lev}, p


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    lowered, p = lower_all()
    for name, lw in lowered.items():
        text = to_hlo_text(lw)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "d_feat": D_FEAT,
        "b_pred": B_PRED,
        "b_train": B_TRAIN,
        "param_count": p,
        "lev_k": LEV_K,
        "lev_l": LEV_L,
        "hidden": list(ref.HIDDEN),
        "adam": {
            "lr": model.ADAM_LR,
            "b1": model.ADAM_B1,
            "b2": model.ADAM_B2,
            "eps": model.ADAM_EPS,
        },
    }
    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'meta.json')}")


if __name__ == "__main__":
    main()
