"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the pytest suite compares the Pallas kernels
against (L1 correctness signal), and the definition the L2 model reuses so
that the AOT-lowered HLO and the oracle share one parameter layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# MLP architecture from the paper (Sec III-C1): 128x64x32x16x1 dense stack
# with ReLU activations, on top of a D-dimensional clustered feature vector.
HIDDEN = (128, 64, 32, 16, 1)


def mlp_param_sizes(d_in: int) -> list:
    """[(W_shape, b_shape), ...] for the dense stack, input dim d_in."""
    sizes = []
    prev = d_in
    for h in HIDDEN:
        sizes.append(((prev, h), (h,)))
        prev = h
    return sizes


def mlp_param_count(d_in: int) -> int:
    return sum(w[0] * w[1] + b[0] for w, b in mlp_param_sizes(d_in))


def unflatten_params(flat, d_in: int):
    """Split a flat f32[P] vector into [(W, b), ...] per dense layer."""
    params = []
    off = 0
    for (wi, wo), (bo,) in mlp_param_sizes(d_in):
        w = flat[off : off + wi * wo].reshape(wi, wo)
        off += wi * wo
        b = flat[off : off + bo]
        off += bo
        params.append((w, b))
    return params


def mlp_forward_ref(flat_params, x):
    """Reference fused-MLP forward: x f32[B, D] -> yhat f32[B].

    ReLU between layers, linear output head. Matches kernels/mlp.py and the
    L2 model bit-for-bit in exact arithmetic (same op order).
    """
    h = x
    params = unflatten_params(flat_params, x.shape[-1])
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i != len(params) - 1:
            h = jnp.maximum(h, 0.0)
    return h[:, 0]


def levenshtein_ref(a, b, la, lb):
    """Reference batched Levenshtein distance.

    a, b: int32[K, L] zero-padded codepoint arrays; la, lb: int32[K] true
    lengths. Returns int32[K]. Vectorized Wagner-Fischer: roll the DP row
    across the characters of `b`, masking steps beyond each pair's length.
    """
    k, l = a.shape
    cols = jnp.arange(l + 1, dtype=jnp.int32)  # [L+1]

    # row[i] = distance(a[:i], b[:j]) after processing j chars of b.
    row0 = jnp.broadcast_to(cols, (k, l + 1)).astype(jnp.int32)

    def step(j, row):
        bj = jax.lax.dynamic_slice_in_dim(b, j, 1, axis=1)  # [K,1]
        sub_cost = jnp.where(a == bj, 0, 1).astype(jnp.int32)  # [K,L]

        def inner(carry, i):
            new_prev = carry  # new_row[i] per pair
            ins = new_prev + 1
            dele = jax.lax.dynamic_slice_in_dim(row, i + 1, 1, axis=1)[:, 0] + 1
            sub = (
                jax.lax.dynamic_slice_in_dim(row, i, 1, axis=1)[:, 0]
                + jax.lax.dynamic_slice_in_dim(sub_cost, i, 1, axis=1)[:, 0]
            )
            val = jnp.minimum(jnp.minimum(ins, dele), sub)
            return val, val

        first = jnp.full((k,), j + 1, dtype=jnp.int32)  # new_row[0] = j+1
        _, rest = jax.lax.scan(inner, first, jnp.arange(l))
        new_row = jnp.concatenate([first[:, None], rest.T], axis=1)
        # only advance pairs whose b actually has a j-th character
        active = (j < lb)[:, None]
        return jnp.where(active, new_row, row)

    row = jax.lax.fori_loop(0, l, step, row0)
    # answer sits at column la for each pair
    return jnp.take_along_axis(row, la[:, None], axis=1)[:, 0]


def levenshtein_py(s1: str, s2: str) -> int:
    """Plain-python oracle-of-the-oracle used in tests."""
    m, n = len(s1), len(s2)
    row = list(range(m + 1))
    for j in range(n):
        new = [j + 1] + [0] * m
        for i in range(m):
            new[i + 1] = min(new[i] + 1, row[i + 1] + 1, row[i] + (s1[i] != s2[j]))
        row = new
    return row[m]


def encode_names(names, l: int):
    """Encode python strings to (int32[K, L], int32[K]) padded arrays."""
    import numpy as np

    k = len(names)
    arr = np.zeros((k, l), dtype=np.int32)
    lens = np.zeros((k,), dtype=np.int32)
    for i, s in enumerate(names):
        s = s[:l]
        arr[i, : len(s)] = [ord(c) for c in s]
        lens[i] = len(s)
    return arr, lens
