"""L1 Pallas kernel: batched Levenshtein distance (feature-clustering hot-spot).

PROFET clusters profiler operation names by Levenshtein distance (Sec III-B).
Building the D x D distance matrix is O(D^2 * L^2) character ops; this kernel
computes a batch of K padded name pairs per call with the Wagner-Fischer DP.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the GPU-idiomatic version
is thread-per-pair with the DP row in registers/shared memory. Here the K
pair dimension maps to vector lanes (whole tile resident in VMEM) and the DP
row rolls in-place via a fori_loop over the characters of `b` with an inner
scan along `a` — the only true data dependence. Per-pair length masking
makes the padded lanes no-ops rather than divergent branches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lev_kernel(a_ref, b_ref, la_ref, lb_ref, o_ref, *, l: int):
    a = a_ref[...]  # [K, L] int32
    b = b_ref[...]
    la = la_ref[...]  # [K]
    lb = lb_ref[...]
    k = a.shape[0]

    cols = jnp.arange(l + 1, dtype=jnp.int32)
    row0 = jnp.broadcast_to(cols, (k, l + 1)).astype(jnp.int32)

    def outer(j, row):
        bj = jax.lax.dynamic_slice_in_dim(b, j, 1, axis=1)  # [K,1]
        sub_cost = jnp.where(a == bj, 0, 1).astype(jnp.int32)

        def inner(carry, i):
            ins = carry + 1
            dele = jax.lax.dynamic_slice_in_dim(row, i + 1, 1, axis=1)[:, 0] + 1
            sub = (
                jax.lax.dynamic_slice_in_dim(row, i, 1, axis=1)[:, 0]
                + jax.lax.dynamic_slice_in_dim(sub_cost, i, 1, axis=1)[:, 0]
            )
            val = jnp.minimum(jnp.minimum(ins, dele), sub)
            return val, val

        first = jnp.full((k,), j + 1, dtype=jnp.int32)
        _, rest = jax.lax.scan(inner, first, jnp.arange(l))
        new_row = jnp.concatenate([first[:, None], rest.T], axis=1)
        active = (j < lb)[:, None]
        return jnp.where(active, new_row, row)

    row = jax.lax.fori_loop(0, l, outer, row0)
    o_ref[...] = jnp.take_along_axis(row, la[:, None], axis=1)[:, 0]


def levenshtein(a, b, la, lb):
    """Batched Levenshtein: (i32[K,L], i32[K,L], i32[K], i32[K]) -> i32[K]."""
    k, l = a.shape
    return pl.pallas_call(
        functools.partial(_lev_kernel, l=l),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.int32),
        interpret=True,
    )(a, b, la, lb)
