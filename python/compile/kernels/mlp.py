"""L1 Pallas kernel: fused 5-layer MLP forward (PROFET's prediction hot-spot).

The whole dense stack (128x64x32x16x1, ReLU between layers) runs as a single
pallas_call so intermediate activations never round-trip to HBM. The batch
dimension is tiled via BlockSpec (TILE_B rows per program); the flat
parameter vector is broadcast to every program and unpacked in-register.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * VMEM budget per program = TILE_B*(D + 128 + 64 + 32 + 16 + 1) f32 for
    activations + P f32 params. With TILE_B=32, D=48, P≈19k this is ~45 KB,
    far under the ~16 MB VMEM ceiling — the kernel is launch/bandwidth
    bound, so a single pass with all layers fused is the right structure.
  * Matmul shapes (TILE_B x D) @ (D x 128) etc. target the MXU with the
    contraction dim padded by the caller to a multiple of 8.
  * interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
    custom-calls; interpret-mode lowers to plain HLO so the same artifact
    runs under the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_B = 32


def _mlp_kernel(params_ref, x_ref, o_ref, *, d_in: int):
    """One program: forward a (TILE_B, D) tile through the full stack."""
    flat = params_ref[...]
    h = x_ref[...]
    off = 0
    layers = ref.mlp_param_sizes(d_in)
    for i, ((wi, wo), (bo,)) in enumerate(layers):
        w = flat[off : off + wi * wo].reshape(wi, wo)
        off += wi * wo
        b = flat[off : off + bo]
        off += bo
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        if i != len(layers) - 1:
            h = jnp.maximum(h, 0.0)
    o_ref[...] = h[:, 0]


def mlp_forward(flat_params, x):
    """Fused MLP forward via Pallas: (f32[P], f32[B, D]) -> f32[B].

    B must be a multiple of TILE_B (the AOT batch is 64).
    """
    b, d = x.shape
    assert b % TILE_B == 0, f"batch {b} not a multiple of {TILE_B}"
    p = flat_params.shape[0]
    grid = (b // TILE_B,)
    return pl.pallas_call(
        functools.partial(_mlp_kernel, d_in=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),  # params: broadcast
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),  # x: batch tile
        ],
        out_specs=pl.BlockSpec((TILE_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(flat_params, x)
