"""L2: the PROFET DNN ensemble member as a JAX compute graph.

The paper's DNN regressor (Sec III-C1): dense 128x64x32x16x1 with ReLU,
Adam (lr 1e-3), loss = MAPE + RMSE. Forward calls the L1 Pallas kernel so
the fused MLP lowers into the same HLO artifact; backward is jax.grad over
the plain-jnp twin of the same graph (identical op order).

All parameters travel as a single flat f32[P] vector so the rust driver can
hold them as one Literal and thread them through train steps without
reconstructing a pytree. Adam moments are two more flat vectors and the step
count a scalar; the train step is a pure function
    (params, m, v, t, x, y) -> (params', m', v', t+1, loss)
executed in a loop from rust/src/dnn/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import mlp as mlp_kernel
from .kernels import ref

ADAM_LR = 1e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
# Ground-truth latencies span ~3 orders of magnitude; the loss mixes a
# scale-free term (MAPE) with an absolute one (RMSE) as in the paper.
MAPE_EPS = 1e-3


def forward(flat_params, x):
    """Prediction path: the fused Pallas MLP."""
    return mlp_kernel.mlp_forward(flat_params, x)


def forward_ref(flat_params, x):
    """Same graph built from plain jnp ops (used for bwd and as oracle)."""
    return ref.mlp_forward_ref(flat_params, x)


def loss_fn(flat_params, x, y):
    """Combined MAPE + RMSE objective (paper Sec III-C1)."""
    yhat = forward_ref(flat_params, x)
    err = yhat - y
    mape = jnp.mean(jnp.abs(err) / jnp.maximum(jnp.abs(y), MAPE_EPS))
    rmse = jnp.sqrt(jnp.mean(err * err) + 1e-12)
    return mape + rmse


def train_step(params, m, v, t, x, y):
    """One Adam step over a minibatch; everything flat f32 / scalar f32."""
    loss, g = jax.value_and_grad(loss_fn)(params, x, y)
    t1 = t + 1.0
    m1 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v1 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m1 / (1.0 - ADAM_B1**t1)
    vhat = v1 / (1.0 - ADAM_B2**t1)
    params1 = params - ADAM_LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return params1, m1, v1, t1, loss


def predict_batch(params, x):
    """AOT entry point for serving: (f32[P], f32[B,D]) -> (f32[B],)."""
    return (forward(params, x),)


def train_step_entry(params, m, v, t, x, y):
    """AOT entry point for training."""
    return train_step(params, m, v, t, x, y)
