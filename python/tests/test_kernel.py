"""L1 correctness: Pallas kernels vs pure-jnp/python oracles.

hypothesis sweeps shapes/values; assert_allclose against ref.py is the core
correctness signal for everything the rust runtime later executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import levenshtein as lev_kernel
from compile.kernels import mlp as mlp_kernel
from compile.kernels import ref


def _rand_params(rng, d):
    return rng.standard_normal(ref.mlp_param_count(d)).astype(np.float32) * 0.1


# ---------------------------------------------------------------- MLP kernel


class TestMlpKernel:
    @pytest.mark.parametrize("d", [8, 16, 48, 64])
    @pytest.mark.parametrize("b", [32, 64, 128])
    def test_matches_ref(self, b, d):
        rng = np.random.default_rng(b * 1000 + d)
        params = _rand_params(rng, d)
        x = rng.standard_normal((b, d)).astype(np.float32)
        got = np.asarray(mlp_kernel.mlp_forward(params, x))
        want = np.asarray(ref.mlp_forward_ref(params, x))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_ragged_batch(self):
        rng = np.random.default_rng(0)
        params = _rand_params(rng, 8)
        x = rng.standard_normal((33, 8)).astype(np.float32)
        with pytest.raises(AssertionError):
            mlp_kernel.mlp_forward(params, x)

    def test_zero_params_zero_output(self):
        d = 16
        params = np.zeros(ref.mlp_param_count(d), dtype=np.float32)
        x = np.ones((32, d), dtype=np.float32)
        got = np.asarray(mlp_kernel.mlp_forward(params, x))
        assert_allclose(got, np.zeros(32, dtype=np.float32), atol=0)

    @settings(max_examples=15, deadline=None)
    @given(
        d=st.sampled_from([4, 8, 24, 48]),
        tiles=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        scale=st.sampled_from([1e-3, 0.1, 1.0, 10.0]),
    )
    def test_hypothesis_sweep(self, d, tiles, seed, scale):
        rng = np.random.default_rng(seed)
        b = tiles * mlp_kernel.TILE_B
        params = _rand_params(rng, d)
        x = (rng.standard_normal((b, d)) * scale).astype(np.float32)
        got = np.asarray(mlp_kernel.mlp_forward(params, x))
        want = np.asarray(ref.mlp_forward_ref(params, x))
        assert_allclose(got, want, rtol=2e-4, atol=1e-4)

    def test_param_count_formula(self):
        # D=48: 48*128+128 + 128*64+64 + 64*32+32 + 32*16+16 + 16*1+1
        assert ref.mlp_param_count(48) == 48 * 128 + 128 + 128 * 64 + 64 + 64 * 32 + 32 + 32 * 16 + 16 + 16 + 1


# -------------------------------------------------------- Levenshtein kernel

OP_NAMES = [
    "Conv2D",
    "Conv2DBackpropFilter",
    "Conv2DBackpropInput",
    "Relu",
    "Relu6",
    "ReluGrad",
    "Relu6Grad",
    "MaxPool",
    "AvgPool",
    "MaxPoolGrad",
    "AvgPoolGrad",
    "MatMul",
    "Softmax",
    "ArgMax",
    "FusedBatchNormV3",
    "FusedBatchNormGradV3",
    "BiasAdd",
    "BiasAddGrad",
    "AssignSubVariableOp",
    "AssignAddVariableOp",
    "DepthwiseConv2dNative",
    "RsqrtGrad",
]


def _pad_pairs(pairs, l=16):
    a, la = ref.encode_names([p[0] for p in pairs], l)
    b, lb = ref.encode_names([p[1] for p in pairs], l)
    return a, b, la, lb


class TestLevenshteinKernel:
    def test_known_distances(self):
        # Paper's worked examples: d(ReLU, ReLU6)=1, d(ReLU, Conv2D)=6,
        # d(MaxPoolGrad, AvgPoolGrad)=3 (case-sensitive over profiler names).
        pairs = [("ReLU", "ReLU6"), ("ReLU", "Conv2D"), ("MaxPoolGrad", "AvgPoolGrad"), ("", "abc")]
        pairs += [("", ""), ("same", "same")]
        while len(pairs) < 8:
            pairs.append(("x", "y"))
        a, b, la, lb = _pad_pairs(pairs)
        got = np.asarray(lev_kernel.levenshtein(a, b, la, lb))
        want = [ref.levenshtein_py(p, q) for p, q in pairs]
        assert got.tolist() == want

    def test_matches_ref_kernel(self):
        rng = np.random.default_rng(7)
        names = [OP_NAMES[i % len(OP_NAMES)] for i in range(32)]
        other = [OP_NAMES[(i * 7 + 3) % len(OP_NAMES)] for i in range(32)]
        a, la = ref.encode_names(names, 24)
        b, lb = ref.encode_names(other, 24)
        got = np.asarray(lev_kernel.levenshtein(a, b, la, lb))
        want = np.asarray(ref.levenshtein_ref(a, b, la, lb))
        assert got.tolist() == want.tolist()
        py = [ref.levenshtein_py(p, q) for p, q in zip(names, other)]
        assert got.tolist() == py

    def test_symmetry(self):
        pairs = [(OP_NAMES[i], OP_NAMES[j]) for i in range(4) for j in range(4)]
        a, b, la, lb = _pad_pairs(pairs, 24)
        fwd = np.asarray(lev_kernel.levenshtein(a, b, la, lb))
        rev = np.asarray(lev_kernel.levenshtein(b, a, lb, la))
        assert fwd.tolist() == rev.tolist()

    @settings(max_examples=20, deadline=None)
    @given(
        data=st.lists(
            st.tuples(
                st.text(alphabet="abcXY26", min_size=0, max_size=10),
                st.text(alphabet="abcXY26", min_size=0, max_size=10),
            ),
            min_size=1,
            max_size=12,
        )
    )
    def test_hypothesis_random_strings(self, data):
        a, b, la, lb = _pad_pairs(data, 12)
        got = np.asarray(lev_kernel.levenshtein(a, b, la, lb))
        want = [ref.levenshtein_py(p, q) for p, q in data]
        assert got.tolist() == want

    def test_triangle_inequality_property(self):
        # d(x,z) <= d(x,y) + d(y,z) over the op-name vocabulary.
        import itertools

        tri = list(itertools.islice(itertools.permutations(OP_NAMES[:8], 3), 40))
        xy = [(x, y) for x, y, _ in tri]
        yz = [(y, z) for _, y, z in tri]
        xz = [(x, z) for x, _, z in tri]
        d = {}
        for key, pairs in (("xy", xy), ("yz", yz), ("xz", xz)):
            a, b, la, lb = _pad_pairs(pairs, 24)
            d[key] = np.asarray(lev_kernel.levenshtein(a, b, la, lb))
        assert (d["xz"] <= d["xy"] + d["yz"]).all()
