"""L2 correctness: model graph, loss, Adam train step, AOT shapes."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref


def _init(rng, d):
    # He-style init matching rust/src/dnn (uniform +-sqrt(6/fan_in)).
    flat = np.zeros(ref.mlp_param_count(d), dtype=np.float32)
    off = 0
    for (wi, wo), (bo,) in ref.mlp_param_sizes(d):
        lim = np.sqrt(6.0 / wi)
        flat[off : off + wi * wo] = rng.uniform(-lim, lim, wi * wo)
        off += wi * wo + bo  # biases stay zero
    return flat


class TestModel:
    def test_forward_matches_pallas(self):
        rng = np.random.default_rng(1)
        d = aot.D_FEAT
        params = _init(rng, d)
        x = rng.standard_normal((aot.B_PRED, d)).astype(np.float32)
        got = np.asarray(model.predict_batch(params, x)[0])
        want = np.asarray(model.forward_ref(params, x))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_loss_positive_and_finite(self):
        rng = np.random.default_rng(2)
        d = aot.D_FEAT
        params = _init(rng, d)
        x = rng.standard_normal((aot.B_TRAIN, d)).astype(np.float32)
        y = np.abs(rng.standard_normal(aot.B_TRAIN)).astype(np.float32) + 0.1
        loss = float(model.loss_fn(params, x, y))
        assert np.isfinite(loss) and loss > 0

    def test_train_step_reduces_loss(self):
        rng = np.random.default_rng(3)
        d = aot.D_FEAT
        p = _init(rng, d)
        m = np.zeros_like(p)
        v = np.zeros_like(p)
        t = np.float32(0.0)
        x = rng.standard_normal((aot.B_TRAIN, d)).astype(np.float32)
        # learnable target: linear function of features
        w = rng.standard_normal(d).astype(np.float32)
        y = np.abs(x @ w) + 1.0
        losses = []
        for _ in range(60):
            p, m, v, t, loss = model.train_step(p, m, v, t, x, y)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.9
        assert float(t) == 60.0

    def test_train_step_shapes_stable(self):
        rng = np.random.default_rng(4)
        d = aot.D_FEAT
        p = _init(rng, d)
        m = np.zeros_like(p)
        v = np.zeros_like(p)
        x = rng.standard_normal((aot.B_TRAIN, d)).astype(np.float32)
        y = np.ones(aot.B_TRAIN, dtype=np.float32)
        p1, m1, v1, t1, loss = model.train_step(p, m, v, np.float32(0), x, y)
        assert p1.shape == p.shape and m1.shape == m.shape and v1.shape == v.shape
        assert np.asarray(loss).shape == ()

    def test_adam_constants_in_meta(self):
        meta_lowered, pcount = aot.lower_all()
        assert set(meta_lowered) == {"mlp_fwd", "mlp_train", "levenshtein"}
        assert pcount == ref.mlp_param_count(aot.D_FEAT)


class TestAot:
    @pytest.fixture(scope="class")
    def lowered(self):
        return aot.lower_all()[0]

    def test_hlo_text_parses_entry(self, lowered):
        for name, lw in lowered.items():
            text = aot.to_hlo_text(lw)
            assert "ENTRY" in text and "ROOT" in text, name
            # 64-bit-id proto issue is avoided by text interchange; text must
            # not be empty or suspiciously small.
            assert len(text) > 500, name

    def test_fwd_hlo_shapes(self, lowered):
        text = aot.to_hlo_text(lowered["mlp_fwd"])
        p = ref.mlp_param_count(aot.D_FEAT)
        assert f"f32[{p}]" in text
        assert f"f32[{aot.B_PRED},{aot.D_FEAT}]" in text

    def test_train_hlo_has_tuple_out(self, lowered):
        text = aot.to_hlo_text(lowered["mlp_train"])
        p = ref.mlp_param_count(aot.D_FEAT)
        # output tuple: params', m', v', t', loss
        assert text.count(f"f32[{p}]") >= 3

    def test_lev_hlo_shapes(self, lowered):
        text = aot.to_hlo_text(lowered["levenshtein"])
        assert f"s32[{aot.LEV_K},{aot.LEV_L}]" in text
